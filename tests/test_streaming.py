"""Epoch-chunked streaming campaigns: churn proven bitwise-stable.

The tentpole contracts, asserted here:

* **(a) zero-churn equivalence** — a segmented streaming run with every
  bank slot attached and no events is bitwise-equal to the monolithic
  ``ArchesSession.run`` on *every* trajectory leaf, for the batched,
  gated and closed-loop paths (the mask selects are identities and the
  boundary re-pack is the identity gather);
* **(b) identity rides the stable UE id** — a 50-draw seeded randomized
  churn sweep: every UE matches a churn-free full-universe reference
  bitwise on *every* leaf for as long as it has been attached since
  slot 0 (link adaptation — OLLA, reported SNR — carries per-UE state,
  and a reattach cold-starts it by design, so post-gap spans diverge
  from the warm reference; the leaves with no carry — ``rsrp``,
  ``executed_flops`` — match on every resident slot, reattach spans
  included); and adding churn of *other* ids never perturbs a resident
  UE's trajectory even though its bank slot moves (re-pack invariance,
  which is what pins the reattach spans bitwise);
* **(c) the sharded collective contract survives re-packing** — a
  forced-8-shard subprocess runs streaming campaigns under a 2-cell
  topology and audits the compiled HLO: the cell-mean ``all-reduce`` is
  the only collective (no gather/permute enters through the admission
  path), plus the in-process jaxpr variant on the 1-device CI mesh.

Churn-boundary KPM semantics (satellite): a detached-then-reattached
UE's window and hysteresis state reset — pinned at the ring layer
(fresh ``ring_init``), the ``DeviceSwitchState`` layer (cold rows start
at ``default_mode``) and the host-replay layer (no pre-detach telemetry
can leak into the first post-attach decision).  Masked cost accounting:
detached slot-UEs carry the ``-1`` mode/bank-slot sentinel, zeroed
KPMs/outputs, zero executed FLOPs, and resident-only ``ai_share``.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.closed_loop import host_replay_closed_loop
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    ExpertBankSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)
from repro.core.streaming import (
    ChurnSchedule,
    gather_permutation,
    gather_state_rows,
    home_cells,
    repack_bank,
)
from repro.core.telemetry import ring_init, ring_push, ring_window_mean
from repro.core.topology import TopologySpec

N_PRB = 6
SEG = 4
N_SLOTS = 12
N_IDS = 5  # stable-id universe of the sweep; anchors {0, 1} never churn
CAPACITY = 4  # bank width: residency may peak at 4 of the 5 ids

#: leaves whose per-slot value is a pure function of (id key, global slot,
#: mode, id channel params) — no route through the ``DeviceLinkState``
#: carry (OLLA offset, reported SNR, cumulative counters), so they must
#: equal the churn-free reference on *every* resident slot, reattach spans
#: included.  Everything else flows through link adaptation, which a
#: reattach cold-starts by design — those leaves match the reference
#: exactly on the attached-since-slot-0 prefix.
MEMORYLESS_KPMS = ("rsrp",)
MEMORYLESS_OUTPUTS = ("executed_flops", "gated_overflow")


def _modes_grid(n_slots: int, n_ids: int) -> tuple:
    """A deterministic AI/MMSE checkerboard over the stable-id axis."""
    return tuple(
        tuple((s + u) % 2 for u in range(n_ids)) for s in range(n_slots)
    )


def _full_residency(n_ids: int, seg: int) -> ChurnSchedule:
    return ChurnSchedule(
        n_ue_ids=n_ids, segment_slots=seg, initial=tuple(range(n_ids))
    )


def assert_history_equal(a, b, *, leaves_only: bool = False):
    """Bitwise equality of two ``BatchedRunHistory``s on every leaf."""
    np.testing.assert_array_equal(a.modes, b.modes, err_msg="modes")
    assert set(a.kpms) == set(b.kpms)
    for k in a.kpms:
        np.testing.assert_array_equal(a.kpms[k], b.kpms[k], err_msg=k)
    assert set(a.outputs) == set(b.outputs)
    for k in a.outputs:
        np.testing.assert_array_equal(a.outputs[k], b.outputs[k], err_msg=k)
    if leaves_only:
        return
    if a.decisions is not None or b.decisions is not None:
        np.testing.assert_array_equal(
            a.decisions, b.decisions, err_msg="decisions"
        )
    if a.n_switches is not None or b.n_switches is not None:
        np.testing.assert_array_equal(
            a.n_switches, b.n_switches, err_msg="n_switches"
        )


# -- ChurnSchedule: declarative form, validation, provenance -------------------


def test_churn_schedule_validation():
    with pytest.raises(ValueError, match="n_ue_ids"):
        ChurnSchedule(n_ue_ids=0, segment_slots=4)
    with pytest.raises(ValueError, match="segment_slots"):
        ChurnSchedule(n_ue_ids=2, segment_slots=0)
    with pytest.raises(ValueError, match="repeats"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4, initial=(1, 1))
    with pytest.raises(ValueError, match="kind"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4,
                      events=((0, 1, "reattach"),))
    with pytest.raises(ValueError, match="slot"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4,
                      events=((-1, 1, "attach"),))
    with pytest.raises(ValueError, match="outside"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4, initial=(2,))
    with pytest.raises(ValueError, match="outside"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4, events=((0, 5, "attach"),))


def test_residency_semantics():
    sched = ChurnSchedule(
        n_ue_ids=3, segment_slots=4, initial=(0,),
        # slot 1 rounds up to the boundary at 4; slot 8 is already one
        events=((1, 1, "attach"), (8, 0, "detach")),
    )
    res = sched.residency(12)
    assert res.shape == (12, 3) and res.dtype == bool
    np.testing.assert_array_equal(res[:, 0], [True] * 8 + [False] * 4)
    np.testing.assert_array_equal(res[:, 1], [False] * 4 + [True] * 8)
    assert not res[:, 2].any()
    # segment length must divide the horizon (one compiled segment shape)
    with pytest.raises(ValueError, match="does not divide"):
        sched.residency(10)
    # events whose effective boundary lies past the horizon never fire —
    # they are not even validated for attach/detach consistency
    past = ChurnSchedule(
        n_ue_ids=3, segment_slots=4, initial=(0,),
        events=((12, 2, "detach"),),  # detach-of-absent, but past slot 12
    )
    np.testing.assert_array_equal(past.residency(12)[:, 2], [False] * 12)


def test_residency_rejects_inconsistent_events():
    with pytest.raises(ValueError, match="already"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4, initial=(0,),
                      events=((4, 0, "attach"),)).residency(8)
    with pytest.raises(ValueError, match="not"):
        ChurnSchedule(n_ue_ids=2, segment_slots=4,
                      events=((4, 1, "detach"),)).residency(8)


def test_validate_capacity_and_cell_blocks():
    sched = ChurnSchedule(n_ue_ids=4, segment_slots=4, initial=(0, 1, 2))
    with pytest.raises(ValueError, match="peaks at 3"):
        sched.validate(8, capacity=2)
    assert sched.validate(8, capacity=4).shape == (8, 4)
    # multi-cell: ids map to home cells in equal blocks and residency must
    # fit each cell's bank block, not just the campaign-wide bank
    with pytest.raises(ValueError, match="does not divide n_ue_ids"):
        ChurnSchedule(n_ue_ids=3, segment_slots=4).validate(
            8, capacity=4, n_cells=2
        )
    with pytest.raises(ValueError, match="bank capacity"):
        ChurnSchedule(n_ue_ids=4, segment_slots=4).validate(
            8, capacity=3, n_cells=2
        )
    with pytest.raises(ValueError, match="cell 0"):
        # 3 cell-0 ids (0, 1) + ... ids {0,1} are cell 0 of 4 ids / 2 cells
        ChurnSchedule(
            n_ue_ids=4, segment_slots=4, initial=(0, 1), events=()
        ).validate(8, capacity=2, n_cells=2)


def test_spec_level_churn_validation_and_provenance():
    churn = ChurnSchedule(
        n_ue_ids=4, segment_slots=4, initial=(0, 1),
        events=((4, 2, "attach"), (4, 0, "detach")),
    )
    spec = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=2, n_slots=8,
        n_prb=N_PRB, churn=churn,
    )
    back = CampaignSpec.from_json(spec.to_json())
    assert back == spec
    assert isinstance(back.churn, ChurnSchedule)
    assert spec_hash(back) == spec_hash(spec)
    # the schedule is part of the campaign fingerprint
    assert spec_hash(spec) != spec_hash(
        dataclasses.replace(spec, churn=dataclasses.replace(
            churn, events=()
        ))
    )
    # paths with no segmented form reject churn at spec time
    with pytest.raises(ValueError, match="no segmented form"):
        CampaignSpec(path="perturbed", n_ues=2, rho=(0.0, 1.0),
                     churn=ChurnSchedule(n_ue_ids=2, segment_slots=1))
    # bank-slot-indexed per-UE policy assignment cannot survive re-packing
    with pytest.raises(ValueError, match="policy_assignment"):
        CampaignSpec(
            path="closed_loop", n_ues=2, n_slots=4,
            policies=(PolicySpec(kind="threshold"),) * 2,
            policy_assignment=(0, 1),
            churn=ChurnSchedule(n_ue_ids=2, segment_slots=2,
                                initial=(0, 1)),
        )
    # infeasible residency fails at spec-compile time, not mid-campaign
    with pytest.raises(ValueError, match="peaks"):
        CampaignSpec(
            path="batched", n_ues=1, n_slots=4,
            churn=ChurnSchedule(n_ue_ids=2, segment_slots=4,
                                initial=(0, 1)),
        )
    with pytest.raises(ValueError, match="ChurnSchedule"):
        ArchesSession(CampaignSpec(n_ues=2, n_slots=4)).run_streaming()


# -- admission pass: re-pack, permutation, state gather ------------------------


def test_repack_bank_stable_partition():
    occ = np.asarray([3, 1, 4, -1])
    resident = np.zeros(6, bool)
    resident[[1, 4, 0, 5]] = True  # 3 drops out; 0 and 5 newly attach
    new = repack_bank(occ, resident)
    # survivors keep their pack order compacted to the front; newcomers
    # append in ascending id order
    np.testing.assert_array_equal(new, [1, 4, 0, 5])
    # unchanged residency is the identity re-pack
    np.testing.assert_array_equal(repack_bank(new, resident), new)
    # cell blocks partition independently: ids 0..2 -> cell 0, 3..5 -> 1
    occ_c = np.asarray([2, -1, 4, 3])
    res_c = np.zeros(6, bool)
    res_c[[0, 2, 3, 4]] = True
    np.testing.assert_array_equal(
        repack_bank(occ_c, res_c, n_cells=2), [2, 0, 4, 3]
    )
    with pytest.raises(ValueError, match="does not divide"):
        repack_bank(occ, resident, n_cells=3)


def test_gather_permutation_and_state_rows():
    prev = np.asarray([3, 1, 4, -1])
    new = np.asarray([1, 4, 0, -1])
    perm = gather_permutation(prev, new)
    np.testing.assert_array_equal(perm, [1, 2, -1, -1])
    state = {"x": jnp.arange(8.0).reshape(4, 2), "n": jnp.arange(4)}
    cold = {"x": jnp.full((4, 2), -9.0), "n": jnp.full((4,), -9)}
    out = gather_state_rows(state, perm, cold)
    np.testing.assert_array_equal(
        np.asarray(out["x"]), [[2, 3], [4, 5], [-9, -9], [-9, -9]]
    )
    np.testing.assert_array_equal(np.asarray(out["n"]), [1, 2, -9, -9])
    # the identity permutation returns every leaf bitwise-unchanged — the
    # zero-churn contract rides on this
    ident = gather_permutation(prev, prev)
    np.testing.assert_array_equal(ident, [0, 1, 2, -1])
    out2 = gather_state_rows(state, np.asarray([0, 1, 2, 3]), cold)
    np.testing.assert_array_equal(np.asarray(out2["x"]), np.asarray(state["x"]))


# -- (a) zero-churn segmented == monolithic, every leaf, every path ------------


@pytest.fixture(scope="module")
def ref_session():
    """Churn-free full-universe reference: N_IDS UEs, monolithic run."""
    spec = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=N_IDS,
        n_slots=N_SLOTS, n_prb=N_PRB, seed=3,
        modes=_modes_grid(N_SLOTS, N_IDS),
    )
    return ArchesSession(spec)


@pytest.fixture(scope="module")
def ref_hist(ref_session):
    return ref_session.run()


def test_zero_churn_batched_bitwise_equals_monolithic(ref_session, ref_hist):
    spec = dataclasses.replace(
        ref_session.spec, churn=_full_residency(N_IDS, SEG)
    )
    hist = ArchesSession(
        spec, ai_params=ref_session.ai_params, engine=ref_session.engine
    ).run()
    assert_history_equal(hist, ref_hist)
    assert hist.attached.all()
    # the re-pack is the identity: every id keeps its own bank slot
    np.testing.assert_array_equal(
        hist.bank_slot, np.tile(np.arange(N_IDS), (N_SLOTS, 1))
    )
    assert hist.ai_share == ref_hist.ai_share


def test_zero_churn_gated_bitwise_equals_monolithic(ref_session):
    base = CampaignSpec(
        path="gated", scenario="churn_cell", n_ues=CAPACITY,
        n_slots=N_SLOTS, n_prb=N_PRB, seed=3,
        modes=_modes_grid(N_SLOTS, CAPACITY),
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=2),
    )
    mono = ArchesSession(base, ai_params=ref_session.ai_params)
    hist_m = mono.run()
    hist_s = ArchesSession(
        dataclasses.replace(base, churn=_full_residency(CAPACITY, SEG)),
        ai_params=ref_session.ai_params, engine=mono.engine,
    ).run()
    assert_history_equal(hist_s, hist_m)
    # gated cost accounting carries over unchanged
    np.testing.assert_array_equal(
        hist_s.executed_flops_per_slot(), hist_m.executed_flops_per_slot()
    )
    assert hist_s.overflow_slot_ues == hist_m.overflow_slot_ues


def _closed_spec(n_ues: int, n_slots: int, **kw) -> CampaignSpec:
    return CampaignSpec(
        path="closed_loop", scenario="churn_cell", n_ues=n_ues,
        n_slots=n_slots, n_prb=N_PRB, seed=5,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
        **kw,
    )


def test_zero_churn_closed_loop_bitwise_equals_monolithic(ref_session):
    base = _closed_spec(CAPACITY, N_SLOTS)
    mono = ArchesSession(base, ai_params=ref_session.ai_params)
    hist_m = mono.run()
    hist_s = ArchesSession(
        dataclasses.replace(base, churn=_full_residency(CAPACITY, SEG)),
        ai_params=ref_session.ai_params, engine=mono.engine,
    ).run()
    assert_history_equal(hist_s, hist_m)
    assert int(hist_s.n_switches.sum()) > 0  # non-vacuous: modes moved


# -- (b) the 50-draw randomized churn property sweep ---------------------------


def _random_churn(rng: np.random.Generator):
    """One legal random schedule over N_IDS ids: anchors {0, 1} always
    attached and never churned; ids {2, 3, 4} toggle at random boundaries
    (event slots land anywhere inside the preceding segment, pinning the
    round-up-to-boundary semantics); occasionally an event past the
    horizon rides along (it must be ignored, not validated)."""
    churnable = [2, 3, 4]
    initial = [0, 1] + [u for u in churnable if rng.random() < 0.5]
    del initial[CAPACITY:]
    resident = set(initial)
    events = []
    for b in (SEG, 2 * SEG):
        for u in churnable:
            if rng.random() < 0.5:
                continue
            slot = int(b - rng.integers(0, SEG))
            if u in resident:
                events.append((slot, u, "detach"))
                resident.discard(u)
            elif len(resident) < CAPACITY:
                events.append((slot, u, "attach"))
                resident.add(u)
    if rng.random() < 0.25:
        events.append((
            int(N_SLOTS + rng.integers(0, SEG)),
            int(rng.choice(churnable)), "detach",
        ))
    return ChurnSchedule(
        n_ue_ids=N_IDS, segment_slots=SEG,
        initial=tuple(initial), events=tuple(events),
    )


def test_streaming_churn_property_sweep(ref_session, ref_hist):
    """50 seeded draws: every slot-UE attached continuously since slot 0
    (anchors included) is bitwise == the churn-free reference on every
    leaf; the carry-free leaves match on every resident slot; detached
    slot-UEs carry sentinels and zero cost; and extra churn of *another*
    id never perturbs a resident trajectory even when it moves bank slots
    (re-pack invariance)."""
    rng = np.random.default_rng(0)
    shared = dict(ai_params=ref_session.ai_params, engine=ref_session.engine)
    repack_moved = False
    for _ in range(50):
        churn = _random_churn(rng)
        spec = dataclasses.replace(
            ref_session.spec, n_ues=CAPACITY, churn=churn
        )
        hist = ArchesSession(spec, **shared).run()
        att = np.asarray(hist.attached, bool)
        np.testing.assert_array_equal(att, churn.residency(N_SLOTS))

        # attached-since-slot-0 prefix (whole columns for the anchors):
        # the link carry gathers along with the UE, so *every* leaf is
        # the churn-free reference, bitwise
        cont = np.cumprod(att, axis=0).astype(bool)
        assert cont[:, 0].all() and cont[:, 1].all()  # anchors covered
        np.testing.assert_array_equal(hist.modes[cont], ref_hist.modes[cont])
        for k in hist.kpms:
            np.testing.assert_array_equal(
                hist.kpms[k][cont], ref_hist.kpms[k][cont], err_msg=k
            )
        for k in hist.outputs:
            np.testing.assert_array_equal(
                hist.outputs[k][cont], ref_hist.outputs[k][cont], err_msg=k
            )

        # carry-free leaves: identity-tied on every resident slot, the
        # reattach spans included
        for k in MEMORYLESS_KPMS:
            np.testing.assert_array_equal(
                hist.kpms[k][att], ref_hist.kpms[k][att], err_msg=k
            )
        for k in MEMORYLESS_OUTPUTS:
            np.testing.assert_array_equal(
                hist.outputs[k][att], ref_hist.outputs[k][att], err_msg=k
            )

        # detached: sentinels, zeroed telemetry, zero executed FLOPs
        assert (hist.modes[~att] == -1).all()
        assert (hist.bank_slot[~att] == -1).all()
        assert (hist.bank_slot[att] >= 0).all()
        for k in hist.kpms:
            assert (hist.kpms[k][~att] == 0).all(), k
        assert (hist.outputs["executed_flops"][~att] == 0).all()
        # ai_share divides by resident slot-UEs, not the id-grid size
        served = (hist.modes == 0) & att
        assert hist.ai_share == pytest.approx(
            served.sum() / att.sum() if att.any() else 0.0
        )
        assert hist.resident_ues_per_slot().tolist() == (
            att.sum(axis=1).tolist()
        )

        # re-pack invariance: give anchor 1 a mid-campaign gap -> every
        # *other* id's history must stay bitwise-identical even though the
        # admission pass now packs them into different bank slots
        churn2 = dataclasses.replace(
            churn,
            events=churn.events + ((SEG, 1, "detach"), (2 * SEG, 1, "attach")),
        )
        hist2 = ArchesSession(
            dataclasses.replace(spec, churn=churn2), **shared
        ).run()
        others = [u for u in range(N_IDS) if u != 1]
        np.testing.assert_array_equal(
            hist2.modes[:, others], hist.modes[:, others]
        )
        np.testing.assert_array_equal(
            hist2.attached[:, others], att[:, others]
        )
        for k in hist.kpms:
            np.testing.assert_array_equal(
                hist2.kpms[k][:, others], hist.kpms[k][:, others], err_msg=k
            )
        for k in hist.outputs:
            np.testing.assert_array_equal(
                hist2.outputs[k][:, others], hist.outputs[k][:, others],
                err_msg=k,
            )
        if not np.array_equal(
            hist2.bank_slot[:, others], hist.bank_slot[:, others]
        ):
            repack_moved = True
    # the invariance must have been exercised, not vacuous: some draw
    # actually moved a surviving UE to a different bank slot
    assert repack_moved


# -- closed loop through churn boundaries (satellite: KPM semantics) -----------


def test_closed_loop_churn_replays_bitwise_through_boundaries(ref_session):
    """10 random closed-loop churn draws: device modes/decisions/switch
    counts replay bitwise through ``host_replay_closed_loop(attached=)``."""
    rng = np.random.default_rng(7)
    base = _closed_spec(3, 8)
    shared = {}
    for _ in range(10):
        initial = [0] + [u for u in (1, 2, 3) if rng.random() < 0.5][:2]
        resident = set(initial)
        events = []
        for u in (1, 2, 3):
            if rng.random() < 0.5:
                continue
            if u in resident:
                events.append((int(4 - rng.integers(0, 4)), u, "detach"))
                resident.discard(u)
            elif len(resident) < 3:
                events.append((int(4 - rng.integers(0, 4)), u, "attach"))
                resident.add(u)
        spec = dataclasses.replace(base, churn=ChurnSchedule(
            n_ue_ids=4, segment_slots=4,
            initial=tuple(initial), events=tuple(events),
        ))
        session = ArchesSession(spec, **shared)
        if not shared:
            shared = dict(ai_params=session.ai_params, engine=session.engine)
        hist = session.run()
        att = np.asarray(hist.attached, bool)
        feats = np.stack(
            [hist.kpms[n] for n in spec.feature_names], axis=-1
        ).astype(np.float32)
        replay = host_replay_closed_loop(
            session.host_policies[0], feats,
            spec.switch.to_config(spec.feature_names), attached=att,
        )
        np.testing.assert_array_equal(hist.modes, replay["active_mode"])
        np.testing.assert_array_equal(hist.decisions, replay["raw_decision"])
        np.testing.assert_array_equal(hist.n_switches, replay["n_switches"])
        assert (hist.modes[~att] == -1).all()
        assert (hist.decisions[~att] == -1).all()


def test_reattach_cold_starts_device_switch_state(ref_session):
    """DeviceSwitchState layer: a detached-then-reattached UE re-enters at
    ``default_mode`` with a cleared register — its pre-detach mode cannot
    survive the gap, and its switch count only reflects in-residency
    boundary transitions (the cold row starts at zero)."""
    spec = dataclasses.replace(_closed_spec(2, 12), churn=ChurnSchedule(
        n_ue_ids=2, segment_slots=4, initial=(0, 1),
        events=((4, 1, "detach"), (8, 1, "attach")),
    ))
    session = ArchesSession(spec, ai_params=ref_session.ai_params)
    hist = session.run()
    default = spec.switch.default_mode
    # the reattach slot is a cold start, whatever mode it left with
    assert hist.modes[8, 1] == default
    np.testing.assert_array_equal(hist.modes[4:8, 1], [-1] * 4)
    # the gap trajectory of UE 1 equals a truncated fresh campaign from
    # slot 8's boundary: replay the whole thing to cross-check switches
    feats = np.stack(
        [hist.kpms[n] for n in spec.feature_names], axis=-1
    ).astype(np.float32)
    replay = host_replay_closed_loop(
        session.host_policies[0], feats,
        spec.switch.to_config(spec.feature_names),
        attached=hist.attached,
    )
    np.testing.assert_array_equal(hist.n_switches, replay["n_switches"])


def test_host_replay_reattach_window_independence():
    """Host-replay layer: nothing observed before a detach (or faked
    during the gap) can influence post-reattach decisions — the ring and
    hysteresis streak restart from scratch at the boundary."""
    from repro.core.policy import ThresholdPolicy
    from repro.core.closed_loop import SwitchConfig

    cfg = SwitchConfig(
        feature_names=("snr",), window_slots=3, hysteresis_slots=2,
        backend="ref",
    )
    policy = ThresholdPolicy(feature_idx=0, threshold=18.0, hysteresis=2.0)
    rng = np.random.default_rng(1)
    post = rng.uniform(10.0, 30.0, size=(4, 1, 1)).astype(np.float32)
    attached = np.ones((10, 1), bool)
    attached[3:6, 0] = False
    a = np.concatenate(
        [np.full((3, 1, 1), 30.0, np.float32),  # strong pre-detach SNR
         np.zeros((3, 1, 1), np.float32), post]
    )
    b = np.concatenate(
        [np.full((3, 1, 1), 5.0, np.float32),  # weak pre-detach SNR
         np.full((3, 1, 1), 99.0, np.float32), post]  # garbage in the gap
    )
    ra = host_replay_closed_loop(policy, a, cfg, attached=attached)
    rb = host_replay_closed_loop(policy, b, cfg, attached=attached)
    for k in ("active_mode", "raw_decision", "pending_mode"):
        np.testing.assert_array_equal(ra[k][6:], rb[k][6:], err_msg=k)
    # ...while the pre-detach spans do differ (the test is not vacuous)
    assert not np.array_equal(ra["raw_decision"][:3], rb["raw_decision"][:3])
    np.testing.assert_array_equal(ra["active_mode"][3:6, 0], [-1] * 3)


def test_ring_layer_reset_pins_window_contents():
    """Ring layer: the admission pass swaps in ``ring_init``, so the first
    post-attach window mean is exactly the mean of post-attach pushes —
    bitwise — no matter what the previous occupant's ring held."""
    stale = ring_init(3, 2)
    for v in ([50.0, -3.0], [41.0, 7.0], [13.0, 13.0]):
        stale = ring_push(stale, jnp.asarray(v, jnp.float32))
    fresh = ring_init(3, 2)  # what the cold start installs
    x = jnp.asarray([19.5, 2.5], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(ring_window_mean(ring_push(fresh, x), 3)), np.asarray(x)
    )
    assert not np.array_equal(
        np.asarray(ring_window_mean(ring_push(stale, x), 3)), np.asarray(x)
    )


# -- run_streaming dispatch ergonomics ----------------------------------------


def test_run_streaming_churn_override(ref_session, ref_hist):
    """``run_streaming(churn=...)`` overrides the spec's schedule (and
    accepts the dict form); ``run()`` on a churn spec auto-dispatches."""
    session = ArchesSession(
        dataclasses.replace(ref_session.spec,
                            churn=_full_residency(N_IDS, SEG)),
        ai_params=ref_session.ai_params, engine=ref_session.engine,
    )
    hist = session.run_streaming()
    assert_history_equal(hist, ref_hist)
    override = ChurnSchedule(
        n_ue_ids=N_IDS, segment_slots=SEG,
        initial=tuple(range(N_IDS)), events=((SEG, 4, "detach"),),
    )
    hist2 = session.run_streaming(churn=dataclasses.asdict(override))
    assert not np.asarray(hist2.attached)[SEG:, 4].any()
    np.testing.assert_array_equal(hist2.modes[:, 0], ref_hist.modes[:, 0])


# -- (c) sharded streaming: collectives audit + re-pack invariance -------------


def test_streaming_sharded_1_device_zero_churn(ref_session):
    """On the CI mesh (1 device) the topology streaming path must still be
    bitwise-equal to the monolithic sharded run — and the streaming scan's
    jaxpr must carry the cell-mean ``psum`` and no gather collective."""
    from repro.core.topology import CellTopology, streaming_open_loop_fn
    from repro.phy.pipeline import init_device_link, resolve_schedule

    base = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=4, n_slots=8,
        n_prb=N_PRB, seed=3, modes=_modes_grid(8, 4),
        topology=TopologySpec(n_cells=2, coupling=0.5,
                              cell_noise_offsets_db=(0.0, 3.0)),
    )
    mono = ArchesSession(base, ai_params=ref_session.ai_params)
    hist_m = mono.run()
    hist_s = ArchesSession(
        dataclasses.replace(base, churn=_full_residency(4, 4)),
        ai_params=ref_session.ai_params, engine=mono.engine,
    ).run()
    assert_history_equal(hist_s, hist_m)

    # jaxpr audit of the streaming program (the multi-device HLO variant
    # runs in the forced-8-shard subprocess below)
    engine = mono.engine
    topo = CellTopology.build(base.topology, 4)
    profile, p = resolve_schedule(engine.cfg, mono.schedule, 4, 4)
    fn = streaming_open_loop_fn(engine, topo, profile)
    ue_keys = jax.vmap(
        lambda u: jax.random.fold_in(jax.random.PRNGKey(0), u)
    )(jnp.arange(4))
    modes = jnp.ones((4, 4), jnp.int32).at[:, 0].set(0)
    # churn_cell is a per-UE scenario: params already carry the (S, U) axes
    assert jnp.ndim(p.noise_var) == 2
    jaxpr = str(jax.make_jaxpr(fn)(
        init_device_link(4), ue_keys, modes, p,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
        jnp.int32(4), jnp.ones(4, bool),
    ))
    assert "psum" in jaxpr
    for collective in ("all_gather", "all_to_all", "ppermute",
                       "pgather", "pswapaxes"):
        assert collective not in jaxpr, collective


_SHARDED_STREAMING_CHECK = r"""
import dataclasses
import numpy as np, jax, jax.numpy as jnp

assert len(jax.devices()) == 8, jax.devices()

from repro.core.session import ArchesSession, CampaignSpec
from repro.core.streaming import ChurnSchedule
from repro.core.topology import (
    CellTopology, TopologySpec, streaming_open_loop_fn,
)
from repro.core.expert_bank import ExecutionMode
from repro.phy.pipeline import (
    BatchedPuschPipeline, init_device_link, resolve_schedule,
)

CAP, IDS, S, SEG = 8, 16, 8, 4
MODES = tuple(tuple((s + u) % 2 for u in range(IDS)) for s in range(S))

# 1) zero-churn streaming == monolithic sharded run, bitwise, 8 shards
base = CampaignSpec(
    path="batched", scenario="churn_cell", n_ues=CAP, n_slots=S, n_prb=6,
    seed=3, modes=tuple(tuple(r[:CAP]) for r in MODES),
    topology=TopologySpec(n_cells=2, coupling=0.3, n_shards=8),
)
mono = ArchesSession(base)
hist_m = mono.run()
zc = dataclasses.replace(base, churn=ChurnSchedule(
    n_ue_ids=CAP, segment_slots=SEG, initial=tuple(range(CAP)),
))
hist_z = ArchesSession(zc, ai_params=mono.ai_params,
                       engine=mono.engine).run()
np.testing.assert_array_equal(hist_z.modes, hist_m.modes)
for k in hist_m.kpms:
    np.testing.assert_array_equal(hist_z.kpms[k], hist_m.kpms[k], err_msg=k)
for k in hist_m.outputs:
    np.testing.assert_array_equal(
        hist_z.outputs[k], hist_m.outputs[k], err_msg=k
    )

# 2) re-pack invariance through an 8-shard churn campaign (coupling=0 so
# the cell-mean multiplier is exactly 1.0 -> bitwise invariant residents)
wide = CampaignSpec(
    path="batched", scenario="churn_cell", n_ues=CAP, n_slots=S, n_prb=6,
    seed=3, modes=MODES,
    topology=TopologySpec(n_cells=2, coupling=0.0, n_shards=8),
    churn=ChurnSchedule(
        n_ue_ids=IDS, segment_slots=SEG,
        initial=(0, 1, 2, 8, 9, 10),
        events=((4, 1, "detach"), (4, 3, "attach"),
                (4, 9, "detach"), (4, 11, "attach")),
    ),
)
s1 = ArchesSession(wide, ai_params=mono.ai_params)
h1 = s1.run()
h2 = ArchesSession(
    dataclasses.replace(wide, churn=dataclasses.replace(
        wide.churn, events=wide.churn.events + ((4, 0, "detach"),)
    )),
    ai_params=mono.ai_params, engine=s1.engine,
).run()
others = [u for u in range(IDS) if u != 0]
np.testing.assert_array_equal(h2.modes[:, others], h1.modes[:, others])
for k in h1.kpms:
    np.testing.assert_array_equal(
        h2.kpms[k][:, others], h1.kpms[k][:, others], err_msg=k
    )
for k in h1.outputs:
    np.testing.assert_array_equal(
        h2.outputs[k][:, others], h1.outputs[k][:, others], err_msg=k
    )
# the extra detach actually moved someone (cell-0 survivors re-packed)
assert not np.array_equal(h2.bank_slot[:, others], h1.bank_slot[:, others])
att = np.asarray(h1.attached, bool)
assert (h1.modes[~att] == -1).all()
assert (np.asarray(h1.outputs["executed_flops"])[~att] == 0).all()

# 3) HLO audit: the streaming scan's only collective is the cell-mean
# all-reduce — the admission path introduces no gather/permute, and the
# gated compaction stays shard-local under the active mask
geng = BatchedPuschPipeline(
    mono.engine.cfg, mono.ai_params, net=mono.net,
    execution_mode=ExecutionMode.GATED, gated_capacity=1,  # per shard
)
topo = CellTopology.build(base.topology, CAP)
profile, p = resolve_schedule(geng.cfg, mono.schedule, SEG, CAP)
assert jnp.ndim(p.noise_var) == 2  # churn_cell is per-UE already
fn = streaming_open_loop_fn(geng, topo, profile)
ue_keys = jax.vmap(
    lambda u: jax.random.fold_in(jax.random.PRNGKey(0), u)
)(jnp.arange(CAP))
modes = jnp.ones((SEG, CAP), jnp.int32).at[:, ::2].set(0)
active = jnp.ones(CAP, bool).at[3].set(False)
args = (init_device_link(CAP), ue_keys, modes, p,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
        jnp.int32(SEG), active)
hlo = jax.jit(fn).lower(*args).compile().as_text()
assert "all-reduce" in hlo, "expected the cell-mean psum to lower"
for bad in ("all-gather", "all-to-all", "collective-permute"):
    assert bad not in hlo, f"cross-device {bad} in the streaming scan"
jax.jit(fn)(*args)  # and it runs

print("STREAMING-SHARDED-8 OK")
"""


def test_streaming_sharded_on_forced_8_device_mesh():
    """Contract (c) at the HLO layer: streaming campaigns on 8 forced host
    devices keep the single-``psum`` collective contract through re-packs
    (subprocess: XLA_FLAGS must precede jax initialization)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + env.get("XLA_FLAGS", "")
    ).strip()
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SHARDED_STREAMING_CHECK],
        env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, (
        f"sharded streaming check failed\n--- stdout ---\n{proc.stdout}\n"
        f"--- stderr ---\n{proc.stderr[-4000:]}"
    )
    assert "STREAMING-SHARDED-8 OK" in proc.stdout


# -- crash-resumable streaming (PR 8) ------------------------------------------
#
# ``run_streaming(checkpoint_dir=...)`` snapshots the scan carry + UE bank
# + host admission state atomically after every completed segment;
# ``max_segments`` is the deterministic kill hook.  Kill at ANY segment
# boundary, resume from the latest checkpoint: the stitched history must
# be bitwise-equal to the uninterrupted run on every leaf.


from repro.checkpoint import CheckpointMismatchError
from repro.core.faults import FaultSpec


_RESUME_CHURN = ChurnSchedule(
    n_ue_ids=N_IDS, segment_slots=SEG, initial=(0, 1, 2),
    events=((SEG, 3, "attach"), (SEG + 2, 2, "detach"),
            (2 * SEG + 1, 2, "attach")),
)


def _resume_roundtrip(sess, tmp_path, kill_after):
    ref = sess.run_streaming()
    d = str(tmp_path / f"ck{kill_after}")
    partial = sess.run_streaming(checkpoint_dir=d, max_segments=kill_after)
    # the killed run produced a prefix: completed segments match the
    # reference, the tail was never executed
    np.testing.assert_array_equal(
        partial.modes[: kill_after * SEG], ref.modes[: kill_after * SEG]
    )
    resumed = sess.run_streaming(resume_from=d)
    assert_history_equal(resumed, ref)
    np.testing.assert_array_equal(resumed.attached, ref.attached)
    np.testing.assert_array_equal(resumed.bank_slot, ref.bank_slot)
    return ref


@pytest.mark.parametrize("kill_after", [1, 2])
def test_resume_closed_loop_bitwise(ref_session, tmp_path, kill_after):
    spec = _closed_spec(CAPACITY, N_SLOTS, churn=_RESUME_CHURN)
    sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    ref = _resume_roundtrip(sess, tmp_path, kill_after)
    assert int(ref.n_switches.sum()) > 0  # non-vacuous


def test_resume_open_and_gated_bitwise(ref_session, tmp_path):
    modes = _modes_grid(N_SLOTS, N_IDS)
    for path in ("batched", "gated"):
        spec = dataclasses.replace(
            ref_session.spec, path=path, n_ues=CAPACITY, modes=modes,
            churn=_RESUME_CHURN,
        )
        sess = ArchesSession(spec, ai_params=ref_session.ai_params)
        _resume_roundtrip(sess, tmp_path / path, 1)


def test_resume_under_faults_bitwise(ref_session, tmp_path):
    """The fault schedule is resolved on the stable-id axis from the spec,
    so a resumed run replays the identical fault stream."""
    spec = _closed_spec(
        CAPACITY, N_SLOTS, churn=_RESUME_CHURN,
        faults=FaultSpec(
            decision_outages=((5, 9),), corruption_spans=((2, 8),),
            corruption_kind="nan", telemetry_drop_prob=0.15, seed=3,
            breaker_trips=2, breaker_window=4, breaker_cooldown=4,
        ),
    )
    sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    ref = _resume_roundtrip(sess, tmp_path, 2)
    assert int(np.asarray(ref.outputs["health_tripped"]).sum()) > 0


def test_resume_refuses_other_spec(ref_session, tmp_path):
    spec = _closed_spec(CAPACITY, N_SLOTS, churn=_RESUME_CHURN)
    sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    d = str(tmp_path / "ck")
    sess.run_streaming(checkpoint_dir=d, max_segments=1)
    other = ArchesSession(
        dataclasses.replace(spec, seed=spec.seed + 1),
        ai_params=ref_session.ai_params,
    )
    with pytest.raises(CheckpointMismatchError, match="different"):
        other.run_streaming(resume_from=d)


def test_resume_from_empty_dir_raises(ref_session, tmp_path):
    spec = _closed_spec(CAPACITY, N_SLOTS, churn=_RESUME_CHURN)
    sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    with pytest.raises(FileNotFoundError):
        sess.run_streaming(resume_from=str(tmp_path / "nope"))


# -- PR 10: pipelined executor, identity fast path, delta checkpoints ----------
#
# The pipelined segment executor overlaps segment k's host assembly /
# checkpoint write with segment k+1's device scan (donated carries, bounded
# double-buffer queue).  Its contract is the repo's standing one: bitwise
# equality to the serial reference on every history leaf, every checkpoint
# and every `on_segment` event, across open-loop/gated/closed-loop/faulted/
# sharded paths (the forced-8-shard subprocess above runs the pipelined
# default).  Incremental delta checkpoints are O(segment): per-step bytes
# must not grow with campaign length, chains must anchor on monolithic
# steps, and a failure inside assembly must never lose a durable prefix.

import repro.core.streaming as streaming_mod
from repro.checkpoint.store import (
    STREAMING_DELTA_KIND,
    checkpoint_kind,
    latest_step,
    list_steps,
)
from repro.core.streaming import is_identity_permutation
from repro.core.telemetry import segment_telemetry


@pytest.fixture(scope="module")
def churn_closed_session(ref_session):
    """One closed-loop churn session shared by the PR-10 suite (the scan
    program compiles once; every run of it is deterministic)."""
    spec = _closed_spec(CAPACITY, N_SLOTS, churn=_RESUME_CHURN)
    return ArchesSession(spec, ai_params=ref_session.ai_params)


def _stream_events(sess, **kw):
    """Run streaming and record the on_segment event stream as plain data."""
    events = []

    def on_segment(ev):
        events.append({
            "seg_idx": ev.seg_idx,
            "n_segments": ev.n_segments,
            "t0": ev.t0,
            "t1": ev.t1,
            "occupant": tuple(int(x) for x in ev.occupant),
            **segment_telemetry(
                ev.segment_history, ev.t0, ev.t1, local=True
            ),
        })
        return False

    hist = sess.run_streaming(on_segment=on_segment, **kw)
    return hist, events


@pytest.mark.parametrize("case", ["closed", "batched", "gated", "faulted"])
def test_pipelined_equals_serial_bitwise(
    ref_session, churn_closed_session, case
):
    if case == "closed":
        sess = churn_closed_session
    elif case == "faulted":
        spec = _closed_spec(
            CAPACITY, N_SLOTS, churn=_RESUME_CHURN,
            faults=FaultSpec(
                decision_outages=((5, 9),), corruption_spans=((2, 8),),
                corruption_kind="nan", telemetry_drop_prob=0.15, seed=3,
                breaker_trips=2, breaker_window=4, breaker_cooldown=4,
            ),
        )
        sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    else:
        spec = dataclasses.replace(
            ref_session.spec, path=case, n_ues=CAPACITY,
            modes=_modes_grid(N_SLOTS, N_IDS), churn=_RESUME_CHURN,
        )
        sess = ArchesSession(spec, ai_params=ref_session.ai_params)
    pipe, ev_pipe = _stream_events(sess, pipeline=True)
    ser, ev_ser = _stream_events(sess, pipeline=False)
    assert_history_equal(pipe, ser)
    np.testing.assert_array_equal(pipe.attached, ser.attached)
    np.testing.assert_array_equal(pipe.bank_slot, ser.bank_slot)
    # identical event streams, telemetry included
    assert ev_pipe == ev_ser
    assert [e["seg_idx"] for e in ev_pipe] == list(range(N_SLOTS // SEG))


def test_pipelined_equals_serial_under_topology(ref_session):
    base = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=4, n_slots=8,
        n_prb=N_PRB, seed=3, modes=_modes_grid(8, 4),
        topology=TopologySpec(n_cells=2, coupling=0.5,
                              cell_noise_offsets_db=(0.0, 3.0)),
        churn=ChurnSchedule(
            n_ue_ids=4, segment_slots=4, initial=(0, 1, 2),
            events=((4, 3, "attach"),),
        ),
    )
    sess = ArchesSession(base, ai_params=ref_session.ai_params)
    pipe, ev_pipe = _stream_events(sess, pipeline=True)
    ser, ev_ser = _stream_events(sess, pipeline=False)
    assert_history_equal(pipe, ser)
    np.testing.assert_array_equal(pipe.bank_slot, ser.bank_slot)
    assert ev_pipe == ev_ser


# -- identity fast path (zero-churn boundaries skip the re-pack gather) --------


def test_identity_permutation_detection():
    assert is_identity_permutation(np.arange(4))
    assert not is_identity_permutation(np.array([1, 0, 2, 3]))
    assert not is_identity_permutation(np.array([0, 1, -1, 3]))  # cold row
    assert not is_identity_permutation(np.array([], np.int64))


def test_identity_fast_path_returns_state_unchanged(monkeypatch):
    state = {"a": jnp.arange(6.0).reshape(3, 2), "b": jnp.ones(3)}
    cold = jax.tree.map(jnp.zeros_like, state)
    perm = np.arange(3)
    out = gather_state_rows(state, perm, cold)
    assert out is state  # no gather dispatched at all
    # forced gather takes the device path and must agree bitwise
    monkeypatch.setattr(streaming_mod, "_FORCE_GATHER", True)
    forced = gather_state_rows(state, perm, cold)
    assert forced is not state
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        state, forced,
    )


def test_zero_churn_fast_path_bitwise_equals_forced_gather(
    ref_session, monkeypatch
):
    spec = dataclasses.replace(
        ref_session.spec, churn=_full_residency(N_IDS, SEG)
    )
    sess = ArchesSession(
        spec, ai_params=ref_session.ai_params, engine=ref_session.engine
    )
    fast = sess.run_streaming()
    monkeypatch.setattr(streaming_mod, "_FORCE_GATHER", True)
    gathered = sess.run_streaming()
    assert_history_equal(fast, gathered)
    np.testing.assert_array_equal(fast.bank_slot, gathered.bank_slot)
    np.testing.assert_array_equal(fast.attached, gathered.attached)


# -- O(segment) telemetry (SegmentEvent.segment_history) -----------------------


def test_segment_history_is_span_local(churn_closed_session):
    """Per-boundary telemetry reduces an O(segment) input: every slot-axis
    leaf of ``segment_history`` covers exactly [t0, t1) no matter how deep
    into the campaign the segment sits — and reduces to the same telemetry
    as the full-campaign view."""
    rows = []

    def on_segment(ev):
        sh = ev.segment_history
        shapes = (
            {np.shape(v)[0] for v in sh.kpms.values()}
            | {np.shape(v)[0] for v in sh.outputs.values()}
            | {
                np.shape(sh.modes)[0], np.shape(sh.attached)[0],
                np.shape(sh.bank_slot)[0], np.shape(sh.decisions)[0],
            }
        )
        rows.append({
            "t0": ev.t0,
            "shapes": shapes,
            "local": segment_telemetry(sh, ev.t0, ev.t1, local=True),
            "full": segment_telemetry(ev.history, ev.t0, ev.t1),
        })
        return False

    churn_closed_session.run_streaming(on_segment=on_segment)
    assert [r["t0"] for r in rows] == [0, SEG, 2 * SEG]
    for r in rows:
        # the structural cost pin: input size is SEG rows, independent of t0
        assert r["shapes"] == {SEG}
        assert r["local"] == r["full"]


def test_segment_telemetry_local_span_mismatch_raises():
    from repro.core.runtime import BatchedRunHistory

    hist = BatchedRunHistory(
        modes=np.zeros((SEG, 2), np.int32), kpms={}, outputs={}
    )
    with pytest.raises(ValueError, match="local span"):
        segment_telemetry(hist, 0, SEG + 1, local=True)


# -- delta checkpoints: O(segment) bytes, chains, failure durability -----------


def test_delta_checkpoint_bytes_independent_of_campaign_length(
    ref_session, churn_closed_session, tmp_path
):
    st12 = {}
    d12 = str(tmp_path / "d12")
    churn_closed_session.run_streaming(checkpoint_dir=d12, stats=st12)
    sess24 = ArchesSession(
        _closed_spec(CAPACITY, 2 * N_SLOTS, churn=_RESUME_CHURN),
        ai_params=ref_session.ai_params, engine=churn_closed_session.engine,
    )
    st24 = {}
    d24 = str(tmp_path / "d24")
    sess24.run_streaming(checkpoint_dir=d24, stats=st24)
    b12, b24 = st12["checkpoint_bytes"], st24["checkpoint_bytes"]
    assert len(b12) == 3 and len(b24) == 6
    # O(seg): per-segment checkpoint bytes never grow with campaign length
    # or with how late in the campaign the segment sits
    assert max(b12 + b24) <= 1.05 * min(b12 + b24)
    # every delta is retained (keep=None) and manifest-tagged
    assert list_steps(d24) == list(range(1, 7))
    for s in list_steps(d24):
        assert checkpoint_kind(
            os.path.join(d24, f"step_{s:08d}")
        ) == STREAMING_DELTA_KIND
    # the legacy monolithic snapshot re-writes the whole horizon: bytes
    # scale with n_slots (and dominate the delta)
    m12, m24 = {}, {}
    churn_closed_session.run_streaming(
        checkpoint_dir=str(tmp_path / "m12"),
        checkpoint_format="monolithic", stats=m12,
    )
    sess24.run_streaming(
        checkpoint_dir=str(tmp_path / "m24"),
        checkpoint_format="monolithic", stats=m24,
    )
    mono_growth = np.mean(m24["checkpoint_bytes"]) - np.mean(
        m12["checkpoint_bytes"]
    )
    delta_growth = abs(np.mean(b24) - np.mean(b12))
    assert mono_growth > 10 * max(delta_growth, 1.0)
    assert max(b12) < min(m12["checkpoint_bytes"])
    assert st12["segments"] == 3 and st12["pipeline"]
    assert st12["checkpoint_format"] == "delta"


def test_monolithic_format_resume_roundtrip(churn_closed_session, tmp_path):
    sess = churn_closed_session
    ref = sess.run_streaming()
    d = str(tmp_path / "mono")
    sess.run_streaming(
        checkpoint_dir=d, checkpoint_format="monolithic", max_segments=2
    )
    # untagged (legacy-format) steps
    assert [
        checkpoint_kind(os.path.join(d, f"step_{s:08d}"))
        for s in list_steps(d)
    ] == [None, None]
    resumed = sess.run_streaming(resume_from=d)
    assert_history_equal(resumed, ref)


def test_mixed_monolithic_then_delta_chain_resumes(
    churn_closed_session, tmp_path
):
    """A directory written by the legacy monolithic writer and continued by
    the delta writer resumes bitwise through the mixed chain."""
    from repro.checkpoint.store import resume_chain

    sess = churn_closed_session
    ref = sess.run_streaming()
    d = str(tmp_path / "mixed")
    sess.run_streaming(
        checkpoint_dir=d, checkpoint_format="monolithic", max_segments=1
    )
    sess.run_streaming(resume_from=d, checkpoint_dir=d, max_segments=1)
    assert resume_chain(d) == (1, [2])
    resumed = sess.run_streaming(resume_from=d)
    assert_history_equal(resumed, ref)


def test_resume_into_fresh_dir_writes_anchor(churn_closed_session, tmp_path):
    """Resuming from one directory while checkpointing into a fresh one
    must anchor the fresh chain with a monolithic step — a delta with no
    on-disk predecessor restores nothing."""
    sess = churn_closed_session
    ref = sess.run_streaming()
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    sess.run_streaming(checkpoint_dir=d1, max_segments=1)
    sess.run_streaming(resume_from=d1, checkpoint_dir=d2, max_segments=1)
    assert list_steps(d2) == [2]
    assert checkpoint_kind(os.path.join(d2, "step_00000002")) is None
    sess.run_streaming(resume_from=d2, checkpoint_dir=d2, max_segments=1)
    assert checkpoint_kind(
        os.path.join(d2, "step_00000003")
    ) == STREAMING_DELTA_KIND
    resumed = sess.run_streaming(resume_from=d2)
    assert_history_equal(resumed, ref)


@pytest.mark.parametrize("pipeline", [True, False])
def test_assembly_failure_preserves_prior_checkpoint(
    churn_closed_session, tmp_path, monkeypatch, pipeline
):
    """An exception inside segment k's host assembly must not lose segment
    k-1's durable checkpoint: the write landed before k's assembly began,
    and the run resumes bitwise from it."""
    sess = churn_closed_session
    ref = sess.run_streaming()
    d = str(tmp_path / "ck")
    real_scatter = streaming_mod._scatter_segment

    def exploding_scatter(full, seg_arr, t0, ids, slots):
        if t0 == SEG:  # first scatter of segment 1
            raise RuntimeError("assembly boom")
        return real_scatter(full, seg_arr, t0, ids, slots)

    monkeypatch.setattr(streaming_mod, "_scatter_segment", exploding_scatter)
    with pytest.raises(RuntimeError, match="assembly boom"):
        sess.run_streaming(checkpoint_dir=d, pipeline=pipeline)
    monkeypatch.setattr(streaming_mod, "_scatter_segment", real_scatter)

    # segment 0's checkpoint survived; nothing for the failed segment
    assert latest_step(d) == 1
    resumed = sess.run_streaming(resume_from=d)
    assert_history_equal(resumed, ref)


def test_on_segment_stop_discards_speculative_segments(
    churn_closed_session, tmp_path
):
    """Graceful drain under the pipelined executor: a truthy on_segment
    stops at that boundary; speculatively launched segments are never
    assembled or checkpointed."""
    sess = churn_closed_session
    ref = sess.run_streaming()
    d = str(tmp_path / "ck")
    seen = []

    def stop_after_two(ev):
        seen.append(ev.seg_idx)
        return ev.seg_idx >= 1

    hist = sess.run_streaming(checkpoint_dir=d, on_segment=stop_after_two)
    assert seen == [0, 1]
    assert list_steps(d) == [1, 2]  # no checkpoint for the discarded launch
    np.testing.assert_array_equal(
        hist.modes[: 2 * SEG], ref.modes[: 2 * SEG]
    )
    assert (np.asarray(hist.modes[2 * SEG:]) == -1).all()
    resumed = sess.run_streaming(resume_from=d)
    assert_history_equal(resumed, ref)


def test_checkpoint_format_validated(churn_closed_session):
    with pytest.raises(ValueError, match="checkpoint_format"):
        churn_closed_session.run_streaming(checkpoint_format="nope")
