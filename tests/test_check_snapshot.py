"""Satellite tests for ``benchmarks.check_snapshot`` — the schema/regression
gate behind ``benchmarks.run --smoke``.

Covers schema-mismatch rejection (unknown schema tag, missing top-level /
``streaming`` / ``gated`` keys, host-fingerprint holes), >20% regression
detection on a comparable host vs. the warning-only path across hosts,
the ``--candidate`` CLI, and the committed default baseline staying
readable by current tooling.
"""

from __future__ import annotations

import copy
import json

import pytest

from benchmarks import check_snapshot as cs


def _payload(schema: str = cs.SCHEMA, rate: float = 100.0) -> dict:
    """Minimal snapshot that satisfies ``validate_schema`` for ``schema``."""
    gated_row = {
        k: (False if k == "bf16_audit_tripped" else rate)
        for k in cs.REQUIRED_GATED_KEYS
    }
    payload = {
        "schema": schema,
        "host": {f: f"host-{f}" for f in cs.HOST_FIELDS},
        "slot_ues_per_s": {"host_loop": rate / 10, "scan_engine": rate},
        "session_slot_ues_per_s": rate,
        "gated": {s: copy.deepcopy(gated_row) for s in cs.REQUIRED_SHARES},
        "campaign_spec_hash": "deadbeef",
    }
    if schema in ("arches-bench-v2", "arches-bench-v3", "arches-bench-v4",
                  "arches-bench-v5"):
        payload["streaming"] = {
            "zero_churn_equal": "bitwise",
            "streaming_slot_ues_per_s": rate,
            "monolithic_slot_ues_per_s": rate,
            "churn_resident_slot_ues_per_s": rate / 2,
            "n_segments": 2,
        }
    if schema == "arches-bench-v5":
        payload["streaming"].update({
            "serial_checkpointed_slot_ues_per_s": rate / 3,
            "pipelined_checkpointed_slot_ues_per_s": rate / 2,
            "pipeline_speedup": 1.5,
            "segment_breakdown_s": {
                "dispatch": 0.001, "wait": 0.01,
                "assembly": 0.002, "checkpoint": 0.003,
            },
            "delta_ckpt_bytes_per_segment": 4096,
            "delta_bytes_length_invariant": "yes",
        })
    if schema in ("arches-bench-v3", "arches-bench-v4", "arches-bench-v5"):
        payload["faults"] = {
            "fault_replay_equal": "bitwise",
            "resume_equal": "bitwise",
            "fault_closed_slot_ues_per_s": rate,
            "checkpointed_slot_ues_per_s": rate / 2,
            "health_tripped_slot_ues": 8,
            "quarantined_slot_ues": 12,
        }
    if schema in ("arches-bench-v4", "arches-bench-v5"):
        payload["service"] = {
            "zero_churn_service_equal": "bitwise",
            "drain_resume_equal": "bitwise",
            "telemetry_exported": 4,
            "telemetry_dropped": 0,
            "service_campaign_wall_s": 1.0,
            "direct_streaming_slot_ues_per_s": rate,
        }
    return payload


def _write(tmp_path, name: str, payload: dict):
    path = tmp_path / name
    path.write_text(json.dumps(payload))
    return path


# -- schema compatibility ------------------------------------------------------


def test_validate_schema_accepts_all_supported_schemas():
    assert cs.validate_schema(_payload("arches-bench-v5"), "x") == []
    # v1..v4 snapshots predate the later sections and must stay
    # readable (BENCH_pr6.json is v1, BENCH_pr9.json is v4)
    assert cs.validate_schema(_payload("arches-bench-v4"), "x") == []
    assert cs.validate_schema(_payload("arches-bench-v3"), "x") == []
    assert cs.validate_schema(_payload("arches-bench-v2"), "x") == []
    assert cs.validate_schema(_payload("arches-bench-v1"), "x") == []


def test_validate_schema_rejects_unknown_schema():
    errs = cs.validate_schema(_payload(schema="arches-bench-v99"), "cand")
    assert any("schema is 'arches-bench-v99'" in e for e in errs)


def test_validate_schema_missing_top_level_keys():
    for key in cs.REQUIRED_KEYS:
        if key == "schema":
            continue  # removing the tag trips the schema check instead
        payload = _payload()
        del payload[key]
        errs = cs.validate_schema(payload, "x")
        assert any(f"missing top-level key {key!r}" in e for e in errs), key


@pytest.mark.parametrize(
    "schema",
    ["arches-bench-v2", "arches-bench-v3", "arches-bench-v4",
     "arches-bench-v5"],
)
def test_validate_schema_v2_plus_requires_streaming_section(schema):
    payload = _payload(schema)
    del payload["streaming"]
    errs = cs.validate_schema(payload, "x")
    assert any("missing 'streaming'" in e for e in errs)
    for key in cs.REQUIRED_STREAMING_KEYS:
        payload = _payload(schema)
        del payload["streaming"][key]
        errs = cs.validate_schema(payload, "x")
        assert any(f"streaming missing {key!r}" in e for e in errs), key


def test_validate_schema_v3_requires_faults_section():
    payload = _payload("arches-bench-v3")
    del payload["faults"]
    errs = cs.validate_schema(payload, "x")
    assert any("missing 'faults'" in e for e in errs)
    for key in cs.REQUIRED_FAULTS_KEYS:
        payload = _payload("arches-bench-v3")
        del payload["faults"][key]
        errs = cs.validate_schema(payload, "x")
        assert any(f"faults missing {key!r}" in e for e in errs), key
    # v2 snapshots predate the section: no faults, no complaint
    assert cs.validate_schema(_payload("arches-bench-v2"), "x") == []


def test_validate_schema_v5_requires_pipelined_streaming_keys():
    """v5 extends the streaming section: the pipelined-executor rates and
    delta-checkpoint measurements are mandatory for v5 snapshots only."""
    for key in cs.REQUIRED_STREAMING_V5_KEYS:
        payload = _payload("arches-bench-v5")
        del payload["streaming"][key]
        errs = cs.validate_schema(payload, "x")
        assert any(f"streaming missing {key!r}" in e for e in errs), key
    # v4 snapshots predate the keys: stripping them is no violation
    payload = _payload("arches-bench-v4")
    assert cs.validate_schema(payload, "x") == []


def test_validate_schema_v4_requires_service_section():
    payload = _payload("arches-bench-v4")
    del payload["service"]
    errs = cs.validate_schema(payload, "x")
    assert any("missing 'service'" in e for e in errs)
    for key in cs.REQUIRED_SERVICE_KEYS:
        payload = _payload("arches-bench-v4")
        del payload["service"][key]
        errs = cs.validate_schema(payload, "x")
        assert any(f"service missing {key!r}" in e for e in errs), key
    # v3 snapshots predate the section: no service, no complaint
    assert cs.validate_schema(_payload("arches-bench-v3"), "x") == []


def test_validate_schema_gated_sweep_holes():
    payload = _payload()
    del payload["gated"]["0.25"]
    errs = cs.validate_schema(payload, "x")
    assert any("missing AI share '0.25'" in e for e in errs)
    payload = _payload()
    del payload["gated"]["1"]["bf16_audit_tripped"]
    errs = cs.validate_schema(payload, "x")
    assert any("missing 'bf16_audit_tripped'" in e for e in errs)


def test_validate_schema_host_fingerprint_holes():
    for field in cs.HOST_FIELDS:
        payload = _payload()
        del payload["host"][field]
        errs = cs.validate_schema(payload, "x")
        assert any(
            f"host fingerprint missing {field!r}" in e for e in errs
        ), field


# -- check(): regression gate --------------------------------------------------


def test_check_baseline_only(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _payload())
    assert cs.check(base) == 0
    assert "schema ok" in capsys.readouterr().out


def test_check_unreadable_and_invalid_baseline(tmp_path):
    assert cs.check(tmp_path / "absent.json") == 1
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert cs.check(bad) == 1
    v99 = _write(tmp_path, "v99.json", _payload(schema="arches-bench-v99"))
    assert cs.check(v99) == 1


def test_check_candidate_is_baseline(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _payload())
    assert cs.check(base, candidate=base) == 0
    assert "nothing to compare" in capsys.readouterr().out


def test_check_regression_on_comparable_host(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _payload(rate=100.0))
    good = _write(tmp_path, "good.json", _payload(rate=85.0))  # -15%
    bad = _write(tmp_path, "bad.json", _payload(rate=70.0))  # -30%
    assert cs.check(base, candidate=good) == 0
    assert "REGRESSION" not in capsys.readouterr().out
    assert cs.check(base, candidate=bad) == 1
    assert "<-- REGRESSION" in capsys.readouterr().out


def test_check_different_host_only_warns(tmp_path, capsys):
    base = _write(tmp_path, "base.json", _payload(rate=100.0))
    slow = _payload(rate=70.0)  # -30%, but on a different machine
    slow["host"]["machine"] = "other-arch"
    cand = _write(tmp_path, "cand.json", slow)
    assert cs.check(base, candidate=cand) == 0
    out = capsys.readouterr().out
    assert "(different host)" in out and "not failing" in out


def test_check_rejects_invalid_candidate(tmp_path):
    base = _write(tmp_path, "base.json", _payload())
    broken = _payload()
    del broken["campaign_spec_hash"]
    cand = _write(tmp_path, "cand.json", broken)
    assert cs.check(base, candidate=cand) == 1


# -- CLI + committed baseline --------------------------------------------------


def test_main_candidate_cli(tmp_path, monkeypatch):
    base = _write(tmp_path, "base.json", _payload(rate=100.0))
    cand = _write(tmp_path, "cand.json", _payload(rate=70.0))
    monkeypatch.setattr(
        "sys.argv", ["check_snapshot", str(base), "--candidate", str(cand)]
    )
    with pytest.raises(SystemExit) as exc:
        cs.main()
    assert exc.value.code == 1
    monkeypatch.setattr("sys.argv", ["check_snapshot", str(base)])
    with pytest.raises(SystemExit) as exc:
        cs.main()
    assert exc.value.code == 0


def test_committed_default_baseline_is_valid():
    """The snapshot committed with the repo must stay readable by the
    tooling every later PR ships — the exact hazard the gate exists for."""
    assert cs.DEFAULT_BASELINE.exists()
    payload = cs._load(cs.DEFAULT_BASELINE)
    assert payload is not None
    assert cs.validate_schema(payload, cs.DEFAULT_BASELINE.name) == []
    assert cs.check(cs.DEFAULT_BASELINE) == 0


@pytest.mark.parametrize(
    "name,schema",
    [("BENCH_pr6.json", "arches-bench-v1"),
     ("BENCH_pr9.json", "arches-bench-v4")],
)
def test_committed_older_snapshots_stay_readable(name, schema):
    """Earlier committed snapshots are the perf *trajectory*: moving the
    default baseline to BENCH_pr10.json must not orphan them."""
    path = cs.DEFAULT_BASELINE.parent / name
    assert path.exists()
    payload = cs._load(path)
    assert payload is not None
    assert payload["schema"] == schema
    assert cs.validate_schema(payload, path.name) == []
    assert cs.check(path) == 0
