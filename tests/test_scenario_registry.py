"""Scenario registry: named lookup, traced-params emission, PoorWindow dedup."""

import numpy as np
import pytest

from repro.phy import scenario as S
from repro.phy.channel import ChannelConfig, channel_params_ue_schedule
from repro.phy.nr import SlotConfig

CFG = SlotConfig(n_prb=24)
NAMED = ("good", "poor", "good_poor_good", "bursty_interference",
         "snr_ramp", "mixed_cell")


def test_all_named_scenarios_registered():
    for name in NAMED + ("multi_cell",):
        assert name in S.scenario_names()


@pytest.mark.parametrize("name", NAMED)
def test_registry_lookup_resolves_to_schedules(name):
    sc = S.get_scenario(name)
    assert sc.name == name and sc.description
    sched = sc.schedule(n_ues=3 if sc.per_ue else None)
    if sc.per_ue:
        assert len(sched) == 3
        assert all(isinstance(s(0), ChannelConfig) for s in sched)
    else:
        assert isinstance(sched(0), ChannelConfig)


@pytest.mark.parametrize("name", NAMED)
def test_every_scenario_emits_traced_channel_params(name):
    """Registry -> device-traceable ChannelParams, homogeneous or per-UE."""
    n_slots, n_ues = 6, 2
    profile, params = S.scenario_params(
        CFG, name, n_slots=n_slots, n_ues=n_ues
    )
    expected = (n_slots, n_ues) if S.get_scenario(name).per_ue else (n_slots,)
    assert params.noise_var.shape == expected
    assert params.sc_mask.shape == expected + (CFG.n_sc,)


def test_per_ue_scenario_requires_n_ues():
    with pytest.raises(ValueError, match="per-UE"):
        S.get_scenario("mixed_cell").schedule()


def test_unknown_scenario_lists_registry():
    with pytest.raises(KeyError, match="good_poor_good"):
        S.get_scenario("no_such_scenario")


def test_register_duplicate_guard():
    with pytest.raises(ValueError, match="already registered"):
        S.register_scenario("good", lambda: S.constant_schedule(S.GOOD))
    # overwrite=True is the explicit escape hatch (restore the original)
    orig = S.get_scenario("good")
    S.register_scenario("good", orig.factory, overwrite=True,
                        description=orig.description)


def test_register_custom_scenario_roundtrip():
    name = "test_custom_scenario"
    try:
        S.register_scenario(
            name, lambda: S.constant_schedule(S.POOR), description="test"
        )
        assert S.make_schedule(name)(0) == S.POOR
    finally:
        S._SCENARIOS.pop(name, None)


# -- PoorWindow: one source of truth for the Fig. 9 boundaries -----------------


def test_poor_window_defaults_shared():
    sched = S.good_poor_good_schedule()
    w = S.POOR_WINDOW
    for slot in (0, w.start - 1, w.start, (w.start + w.end) // 2, w.end - 1,
                 w.end, w.end + 50):
        in_window = slot in w
        assert sched(slot).interference == in_window
        assert S.condition_label(slot) == (0 if in_window else 1)


def test_poor_window_custom_bounds_consistent():
    sched = S.good_poor_good_schedule(poor_start=3, poor_end=5)
    got = [sched(s).interference for s in range(7)]
    assert got == [False, False, False, True, True, False, False]
    labels = [S.condition_label(s, poor_start=3, poor_end=5) for s in range(7)]
    assert labels == [1, 1, 1, 0, 0, 1, 1]


# -- new scenario semantics ----------------------------------------------------


def test_bursty_interference_duty_cycle():
    sched = S.bursty_interference_schedule(period=8, burst_slots=3)
    on = [sched(s).interference for s in range(16)]
    assert on == ([True] * 3 + [False] * 5) * 2
    with pytest.raises(ValueError, match="burst_slots"):
        S.bursty_interference_schedule(period=4, burst_slots=5)
    with pytest.raises(ValueError, match="period"):
        S.bursty_interference_schedule(period=0, burst_slots=0)


def test_snr_ramp_sweeps_and_returns():
    sched = S.snr_ramp_schedule(snr_hi_db=14.0, snr_lo_db=2.0, period=8)
    snrs = [sched(s).snr_db for s in range(9)]
    assert snrs[0] == pytest.approx(14.0)
    assert snrs[4] == pytest.approx(2.0)  # trough at period/2
    assert snrs[8] == pytest.approx(14.0)  # periodic
    assert not any(sched(s).interference for s in range(9))
    assert all(np.diff(snrs[:5]) < 0) and all(np.diff(snrs[4:]) > 0)
    # an odd period must still repeat exactly every `period` slots
    odd = S.snr_ramp_schedule(period=7)
    assert [odd(s).snr_db for s in range(7)] == pytest.approx(
        [odd(s + 7).snr_db for s in range(7)]
    )
    assert odd(3).snr_db != odd(0).snr_db
    with pytest.raises(ValueError, match="period"):
        S.snr_ramp_schedule(period=0)


def test_mixed_cell_is_heterogeneous():
    scheds = S.make_schedule("mixed_cell", n_ues=4)
    # UE 0 stays clean; UE 1/2 see interference at some slot
    assert not any(scheds[0](s).interference for s in range(30))
    assert any(scheds[1](s).interference for s in range(30))
    assert any(scheds[2](s).interference for s in range(30))
    # the per-UE stack is traced-schedule compatible (shared profile)
    profile, params = channel_params_ue_schedule(CFG, scheds, 6)
    assert params.interf_on.shape == (6, 4)


def test_multi_cell_composes_registry_entries_per_cell():
    scheds = S.make_schedule(
        "multi_cell", n_ues=6, n_cells=3,
        per_cell_scenario=("good", "poor", "good"),
    )
    assert len(scheds) == 6
    # contiguous equal cells: UEs {0,1} good, {2,3} poor, {4,5} good
    for u in (0, 1, 4, 5):
        assert not any(scheds[u](s).interference for s in range(10))
    for u in (2, 3):
        assert all(scheds[u](s).interference for s in range(10))
    # shorter name lists cycle over cells
    cycled = S.make_schedule("multi_cell", n_ues=4, n_cells=4,
                             per_cell_scenario=("good", "poor"))
    assert not cycled[0](0).interference and not cycled[2](0).interference
    assert cycled[1](0).interference and cycled[3](0).interference
    # the per-cell stack lowers to traced per-UE params (shared profile)
    profile, params = channel_params_ue_schedule(CFG, scheds, 5)
    assert params.interf_on.shape == (5, 6)


def test_multi_cell_error_paths():
    """Misconfiguration fails at schedule build time with a clear message,
    not as a shape error deep in the scan."""
    with pytest.raises(ValueError, match="does not divide"):
        S.make_schedule("multi_cell", n_ues=4, n_cells=3)
    with pytest.raises(KeyError, match="registered"):
        S.make_schedule("multi_cell", n_ues=4, n_cells=2,
                        per_cell_scenario=("good", "no_such_scenario"))
    with pytest.raises(ValueError, match="per-UE"):
        S.make_schedule("multi_cell", n_ues=4, n_cells=2,
                        per_cell_scenario=("good", "mixed_cell"))
    with pytest.raises(ValueError, match="at least one"):
        S.make_schedule("multi_cell", n_ues=4, n_cells=2,
                        per_cell_scenario=())
    with pytest.raises(ValueError, match="n_cells"):
        S.make_schedule("multi_cell", n_ues=4, n_cells=0)
