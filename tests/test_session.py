"""ArchesSession: one declarative call == each legacy entry point, bitwise.

The session API's contract has three legs, all asserted here:

* **Provenance** — ``CampaignSpec`` survives a JSON serialize/deserialize
  round trip (every campaign below is run from its *restored* spec) and
  hashes stably.
* **Dispatch equivalence** — ``ArchesSession(spec).run()`` reproduces,
  bitwise on mode trajectories (and physical KPM leaves where compared),
  the host loop, the open-loop batched engine, the closed loop, gated
  execution, and the perturbation sweep built by hand through the legacy
  entry points.
* **Per-UE heterogeneity** — a ``mixed_cell`` campaign where UEs run
  different channel schedules *and* different exported policies matches
  its per-UE host replay bitwise (the ROADMAP open item, retired).

Plus the satellite utilities: the deprecation shim on the old
``closed_loop=True`` kwarg constructor, ``ArchesRuntime.from_spec``, and
``suggest_gated_capacity``.
"""

import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core.closed_loop import SwitchConfig, host_replay_closed_loop
from repro.core.expert_bank import ExecutionMode
from repro.core.policy import ThresholdPolicy, profile_and_fit_tree
from repro.core.runtime import (
    ArchesRuntime,
    BatchedRunHistory,
    suggest_gated_capacity,
)
from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    ExecutionPath,
    ExpertBankSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline, PuschPipeline
from repro.phy.scenario import good_poor_good_schedule

N_SLOTS, N_UES = 12, 2
POOR_ARGS = (("poor_start", 4), ("poor_end", 8))
SCHED = good_poor_good_schedule(poor_start=4, poor_end=8)
CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)


def restored(spec: CampaignSpec) -> CampaignSpec:
    """Round-trip through JSON first — every campaign runs from provenance."""
    out = CampaignSpec.from_json(spec.to_json())
    assert out == spec
    assert spec_hash(out) == spec_hash(spec)
    return out


@pytest.fixture(scope="module")
def legacy_params():
    """What the spec defaults must reproduce: params from PRNGKey(0)."""
    return init_params(jax.random.PRNGKey(0), CFG, NET)


@pytest.fixture(scope="module")
def legacy_engine(legacy_params):
    return BatchedPuschPipeline(CFG, legacy_params, net=NET)


# -- spec round trip -----------------------------------------------------------


def test_spec_json_round_trip_full_nesting():
    spec = CampaignSpec(
        path="closed_loop",
        scenario="mixed_cell",
        scenario_args=(("poor_start", 3), ("poor_end", 7)),
        n_ues=4,
        n_slots=9,
        seed=11,
        modes=((0, 1, 1, 0),) * 9,
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=2),
        policies=(
            PolicySpec(kind="tree", depth=3, train_slots=6),
            PolicySpec(kind="threshold", feature="snr", threshold=17.5,
                       hysteresis=1.5),
        ),
        policy_assignment=(0, 1, 0, 1),
        switch=SwitchSpec(window_slots=3, hysteresis_slots=2, period_slots=2,
                          backend="ref"),
    )
    back = CampaignSpec.from_json(spec.to_json())
    assert back == spec
    assert back.to_json() == spec.to_json()
    assert spec_hash(back) == spec_hash(spec)
    # JSON lists became the frozen spec's tuples again
    assert isinstance(back.modes[0], tuple)
    assert isinstance(back.policies[0], PolicySpec)
    assert back.scenario_kwargs == {"poor_start": 3, "poor_end": 7}


def test_spec_json_round_trip_perturbed_and_defaults():
    for spec in (
        CampaignSpec(),
        CampaignSpec(path="perturbed", n_ues=3, n_slots=5,
                     rho=(0.0, 0.5, 1.0)),
    ):
        back = CampaignSpec.from_json(spec.to_json())
        assert back == spec and spec_hash(back) == spec_hash(spec)


def test_spec_validation():
    with pytest.raises(ValueError, match="execution path"):
        CampaignSpec(path="warp_drive")
    with pytest.raises(ValueError, match="policy kind"):
        PolicySpec(kind="oracle")
    with pytest.raises(ValueError, match="execution mode"):
        ExpertBankSpec(execution_mode="sometimes")
    with pytest.raises(ValueError, match="policy_assignment"):
        CampaignSpec(n_ues=2, policies=(PolicySpec(),),
                     policy_assignment=(0, 0, 0))
    with pytest.raises(ValueError, match="out of range"):
        CampaignSpec(n_ues=2, policies=(PolicySpec(),),
                     policy_assignment=(0, 1))
    with pytest.raises(ValueError, match="empty"):
        CampaignSpec(n_ues=2, policy_assignment=(3, 7))
    with pytest.raises(ValueError, match="one UE"):
        ArchesSession(CampaignSpec(path="host", n_ues=2,
                                   policies=(PolicySpec(),)))
    with pytest.raises(ValueError, match="rho"):
        ArchesSession(CampaignSpec(path="perturbed"))
    with pytest.raises(ValueError, match="PolicySpec"):
        ArchesSession(CampaignSpec(path="closed_loop"))
    # per-UE scenarios have no single schedule for the host slot loop
    with pytest.raises(ValueError, match="homogeneous"):
        ArchesSession(CampaignSpec(path="host", scenario="mixed_cell",
                                   n_ues=1, policies=(PolicySpec(),)))
    # several policies must say which UE runs which table — a silent
    # all-table-0 assignment would ignore the declared second policy
    with pytest.raises(ValueError, match="policy_assignment"):
        ArchesSession(CampaignSpec(
            path="closed_loop", n_ues=2,
            policies=(PolicySpec(), PolicySpec(kind="threshold")),
        ))


def test_heterogeneous_tree_training_ignores_foreign_scenario_args():
    """A per-UE campaign's scenario kwargs belong to its own factory; tree
    training must fall back to good_poor_good — with the poor window scaled
    into the short training horizon, so the labels stay two-class and the
    fitted tree is not a constant."""
    spec = CampaignSpec(
        path="closed_loop", scenario="mixed_cell",
        scenario_args=(("period", 8), ("burst_slots", 3)),
        n_ues=2, n_slots=6,
        policies=(PolicySpec(kind="tree", train_slots=6),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    )
    session = ArchesSession(spec)
    hist = session.run()
    assert hist.modes.shape == (6, 2)
    leaves = session.host_policies[0].tree.leaf_values
    assert {0.0, 1.0} <= set(np.asarray(leaves).tolist()), (
        "training fell back to a single-class window: constant tree"
    )


def test_train_scenario_args_reach_the_training_factory():
    spec = CampaignSpec(
        path="closed_loop", scenario="mixed_cell", n_ues=2, n_slots=6,
        policies=(PolicySpec(
            kind="tree", train_slots=6, train_scenario="good_poor_good",
            train_scenario_args=(("poor_start", 2), ("poor_end", 4)),
        ),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    )
    assert CampaignSpec.from_json(spec.to_json()) == spec
    sched = ArchesSession(spec)._train_schedule(spec.policies[0])
    assert [sched(s).interference for s in range(6)] == [
        False, False, True, True, False, False,
    ]


def test_spec_accepts_device_arrays():
    """modes/rho given as jax or numpy arrays normalize into the JSON-stable
    tuple form (the spec's provenance contract must survive any input the
    engine's normalize_modes would accept)."""
    import jax.numpy as jnp

    spec = CampaignSpec(path="batched", n_ues=2, n_slots=3,
                        modes=jnp.ones((3, 2), jnp.int32))
    assert spec.modes == ((1, 1),) * 3
    assert CampaignSpec.from_json(spec.to_json()) == spec


def test_spec_accepts_enum_members_and_stays_serializable():
    """Enum members normalize to their string value — provenance must not
    depend on whether the author wrote the enum or its JSON form."""
    spec = CampaignSpec(
        path=ExecutionPath.GATED,
        bank=ExpertBankSpec(execution_mode=ExecutionMode.GATED),
        n_ues=2, n_slots=2,
    )
    assert spec.path == "gated" and spec.bank.execution_mode == "gated"
    assert spec == CampaignSpec(
        path="gated", bank=ExpertBankSpec(execution_mode="gated"),
        n_ues=2, n_slots=2,
    )
    assert CampaignSpec.from_json(spec.to_json()) == spec


def test_host_replay_rejects_policy_idx_without_sequence():
    policy = ThresholdPolicy(feature_idx=0, threshold=0.0)
    cfg = SwitchConfig(feature_names=("f",), window_slots=1)
    feats = np.zeros((2, 2, 1), np.float32)
    with pytest.raises(ValueError, match="not a sequence"):
        host_replay_closed_loop(policy, feats, cfg, policy_idx=(0, 0))
    # negative indexes would silently wrap through Python list indexing
    with pytest.raises(ValueError, match="outside"):
        host_replay_closed_loop([policy, policy], feats, cfg,
                                policy_idx=(-1, 0))


def test_host_path_honors_policy_assignment(legacy_params):
    """The host UE may be assigned any declared table — a spec assigning
    policies[1] must not silently run policies[0]."""
    spec = CampaignSpec(
        path="host", scenario="good", n_ues=1, n_slots=4,
        policies=(
            PolicySpec(kind="threshold", feature="snr", threshold=18.0),
            # degenerate gate: anything below 99 dB -> AI (always mode 0)
            PolicySpec(kind="threshold", feature="snr", threshold=99.0),
        ),
        policy_assignment=(1,),
        switch=SwitchSpec(window_slots=1),
    )
    hist = ArchesSession(spec, ai_params=legacy_params).run()
    assert (hist.modes[1:, 0] == 0).all()  # the always-AI table ran


def test_host_path_rejects_silently_dropped_knobs():
    with pytest.raises(ValueError, match="hysteresis"):
        ArchesSession(CampaignSpec(
            path="host", n_ues=1, policies=(PolicySpec(),),
            switch=SwitchSpec(hysteresis_slots=3),
        ))


def test_gated_path_rejects_selected_only_bank():
    with pytest.raises(ValueError, match="un-gated"):
        ArchesSession(CampaignSpec(
            path="gated", n_ues=2, n_slots=2,
            bank=ExpertBankSpec(execution_mode="selected_only"),
        ))


def test_gated_path_normalizes_bank_without_mutating_spec():
    spec = CampaignSpec(path="gated", n_ues=2, n_slots=2)
    session = ArchesSession(spec)
    assert ExecutionMode.coerce(session.bank_spec.execution_mode) is (
        ExecutionMode.GATED
    )
    assert spec.bank.execution_mode == "concurrent"  # provenance untouched


# -- dispatch equivalence vs the legacy entry points ---------------------------


def test_batched_session_matches_legacy_engine(legacy_engine):
    modes = np.tile(np.asarray([[0, 1]], np.int32), (N_SLOTS, 1))
    spec = restored(CampaignSpec(
        path="batched", scenario="good_poor_good", scenario_args=POOR_ARGS,
        n_ues=N_UES, n_slots=N_SLOTS, seed=3,
        modes=tuple(map(tuple, modes)),
    ))
    hist = ArchesSession(spec).run()
    _, traj = legacy_engine.run(
        SCHED, modes, n_slots=N_SLOTS, n_ues=N_UES,
        key=jax.random.PRNGKey(3),
    )
    np.testing.assert_array_equal(hist.modes, modes)
    np.testing.assert_array_equal(
        hist.kpms["sinr"], np.asarray(traj["kpms"]["aerial"]["sinr"])
    )
    np.testing.assert_array_equal(
        hist.outputs["tb_ok"], np.asarray(traj["tb_ok"])
    )


def test_gated_session_matches_legacy_engine(legacy_params):
    modes = np.ones((N_SLOTS, N_UES), np.int32)
    modes[:, 0] = 0
    spec = restored(CampaignSpec(
        path="gated", scenario="good_poor_good", scenario_args=POOR_ARGS,
        n_ues=N_UES, n_slots=N_SLOTS, seed=3,
        modes=tuple(map(tuple, modes)),
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=1),
    ))
    hist = ArchesSession(spec).run()
    legacy = BatchedPuschPipeline(
        CFG, legacy_params, net=NET,
        execution_mode=ExecutionMode.GATED, gated_capacity=1,
    )
    _, traj = legacy.run(
        SCHED, modes, n_slots=N_SLOTS, n_ues=N_UES,
        key=jax.random.PRNGKey(3),
    )
    np.testing.assert_array_equal(
        hist.kpms["sinr"], np.asarray(traj["kpms"]["aerial"]["sinr"])
    )
    np.testing.assert_array_equal(
        hist.outputs["gated_overflow"], np.asarray(traj["gated_overflow"])
    )
    assert hist.overflow_slot_ues == 0


def test_closed_loop_session_matches_legacy_runtime(legacy_engine):
    spec = restored(CampaignSpec(
        path="closed_loop", scenario="good_poor_good",
        scenario_args=POOR_ARGS, n_ues=N_UES, n_slots=N_SLOTS, seed=7,
        policies=(PolicySpec(kind="tree", depth=2, train_ues=2),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    ))
    hist = ArchesSession(spec).run()

    # the legacy construction: hand-trained policy + kwarg-soup runtime
    policy = profile_and_fit_tree(
        legacy_engine, SCHED, n_slots=N_SLOTS, n_ues=2, depth=2
    )
    sw_cfg = SwitchConfig(
        feature_names=SELECTED_KPMS, window_slots=2, backend="ref"
    )
    with pytest.warns(DeprecationWarning, match="from_spec"):
        runtime = ArchesRuntime(
            closed_loop=True, engine=legacy_engine,
            device_policy=policy.to_device(), switch_config=sw_cfg,
        )
    legacy_hist = runtime.run_batched(
        SCHED, n_slots=N_SLOTS, n_ues=N_UES, key=jax.random.PRNGKey(7)
    )
    np.testing.assert_array_equal(hist.modes, legacy_hist.modes)
    np.testing.assert_array_equal(hist.decisions, legacy_hist.decisions)
    np.testing.assert_array_equal(hist.n_switches, legacy_hist.n_switches)
    # non-vacuous: the campaign actually switched
    assert hist.n_switches.sum() > 0


def test_host_session_matches_legacy_loop(legacy_params):
    from repro.core.dapp import DApp, connect_dapp
    from repro.core.e3 import E3Agent

    threshold = PolicySpec(kind="threshold", feature="snr", threshold=18.0,
                           hysteresis=2.0)
    spec = restored(CampaignSpec(
        path="host", scenario="good_poor_good", scenario_args=POOR_ARGS,
        n_ues=1, n_slots=10,
        policies=(threshold,),
        switch=SwitchSpec(window_slots=2, ttl_slots=8),
    ))
    hist = ArchesSession(spec).run()
    assert isinstance(hist, BatchedRunHistory)
    assert hist.modes.shape == (10, 1)

    pipe = PuschPipeline(CFG, legacy_params, net=NET)
    agent = E3Agent()
    policy = ThresholdPolicy(
        feature_idx=SELECTED_KPMS.index("snr"), threshold=18.0, hysteresis=2.0
    )
    dapp = DApp(policy, SELECTED_KPMS, window_slots=2)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        pipe.make_slot_fn(SCHED), agent,
        default_mode=1, fail_safe_mode=1, ttl_slots=8, keep_outputs=True,
    )
    legacy_hist = runtime.run(range(10))
    np.testing.assert_array_equal(hist.modes[:, 0], legacy_hist.modes)
    np.testing.assert_array_equal(
        hist.kpms["snr"][:, 0], legacy_hist.kpm_series("snr")
    )


def test_perturbed_session_matches_legacy_engine(legacy_engine):
    rho = (0.0, 0.6)
    spec = restored(CampaignSpec(
        path="perturbed", scenario="good", n_ues=len(rho), n_slots=6,
        seed=5, rho=rho,
    ))
    hist = ArchesSession(spec).run()
    from repro.phy.scenario import make_schedule

    _, traj = legacy_engine.run_perturbed(
        make_schedule("good"), np.asarray(rho, np.float32),
        n_slots=6, key=jax.random.PRNGKey(5),
    )
    np.testing.assert_array_equal(
        hist.kpms["sinr"], np.asarray(traj["kpms"]["aerial"]["sinr"])
    )
    np.testing.assert_array_equal(
        hist.outputs["tb_ok"], np.asarray(traj["tb_ok"])
    )
    assert (hist.modes == 1).all()  # stage 1 is MMSE-only


# -- per-UE heterogeneity (the retired ROADMAP item) ---------------------------


def test_heterogeneous_scenario_and_policies_match_per_ue_replay():
    """Four UEs, per-UE channel schedules, two different policies — the
    device campaign must equal the per-UE host replay bitwise, and the two
    policy groups must actually behave differently (non-vacuous)."""
    spec = restored(CampaignSpec(
        path="closed_loop", scenario="mixed_cell", n_ues=4, n_slots=N_SLOTS,
        seed=1,
        policies=(
            PolicySpec(kind="threshold", feature="snr", threshold=18.0,
                       hysteresis=2.0),
            # degenerate gate: anything below 99 dB -> AI (always mode 0)
            PolicySpec(kind="threshold", feature="snr", threshold=99.0),
        ),
        policy_assignment=(0, 1, 0, 1),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    ))
    session = ArchesSession(spec)
    hist = session.run()

    feats = np.stack(
        [hist.kpms[n] for n in spec.feature_names], axis=-1
    ).astype(np.float32)
    replay = host_replay_closed_loop(
        list(session.host_policies), feats,
        spec.switch.to_config(spec.feature_names),
        policy_idx=spec.policy_assignment,
    )
    np.testing.assert_array_equal(hist.modes, replay["active_mode"])
    np.testing.assert_array_equal(hist.decisions, replay["raw_decision"])
    # the packaged oracle reproduces the hand-built replay
    np.testing.assert_array_equal(
        session.host_replay(hist)["active_mode"], replay["active_mode"]
    )

    # policy 1 forces AI from its first committed decision onward; policy 0
    # on the clean UE 0 keeps MMSE — two UEs demonstrably ran different
    # policies in one scan
    assert (hist.modes[2:, 1] == 0).all() and (hist.modes[2:, 3] == 0).all()
    assert not np.array_equal(hist.modes[:, 0], hist.modes[:, 1])


def test_per_ue_schedules_match_solo_homogeneous_runs(legacy_engine):
    """Per-UE params preserve the engine's trajectory-identity contract:
    each UE of a heterogeneous campaign equals the same UE of a homogeneous
    campaign under its own schedule (same keys), bitwise."""
    from repro.phy.scenario import GOOD, POOR, constant_schedule

    key = jax.random.PRNGKey(3)
    good, poor = constant_schedule(GOOD), constant_schedule(POOR)
    _, het = legacy_engine.run(
        [good, poor], 1, n_slots=5, n_ues=2, key=key
    )
    _, hg = legacy_engine.run(good, 1, n_slots=5, n_ues=2, key=key)
    _, hp = legacy_engine.run(poor, 1, n_slots=5, n_ues=2, key=key)
    for leaf in ("tb_ok", "mcs"):
        np.testing.assert_array_equal(
            np.asarray(het[leaf])[:, 0], np.asarray(hg[leaf])[:, 0]
        )
        np.testing.assert_array_equal(
            np.asarray(het[leaf])[:, 1], np.asarray(hp[leaf])[:, 1]
        )
    sinr = lambda t: np.asarray(t["kpms"]["aerial"]["sinr"])
    np.testing.assert_array_equal(sinr(het)[:, 0], sinr(hg)[:, 0])
    np.testing.assert_array_equal(sinr(het)[:, 1], sinr(hp)[:, 1])


# -- runtime construction: from_spec + the deprecation shim --------------------


def test_legacy_closed_loop_kwargs_warn():
    with pytest.warns(DeprecationWarning, match="from_spec"):
        ArchesRuntime(
            closed_loop=True, engine=object(), device_policy=object(),
            switch_config=object(),
        )
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="closed_loop"):
            ArchesRuntime(closed_loop=True)


def test_from_spec_builds_quietly_and_runs(legacy_engine):
    spec = CampaignSpec(
        path="closed_loop", scenario="good_poor_good",
        scenario_args=POOR_ARGS, n_ues=N_UES, n_slots=6, seed=7,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        switch=SwitchSpec(window_slots=2, backend="ref"),
    )
    session = ArchesSession(spec)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        runtime = ArchesRuntime.from_spec(
            spec, engine=legacy_engine, device_policy=session.device_policy
        )
    assert runtime.closed_loop
    assert runtime.switch_config.feature_names == spec.feature_names
    assert runtime.switch_config.window_slots == 2
    hist = runtime.run_batched(
        SCHED, n_slots=6, n_ues=N_UES, key=jax.random.PRNGKey(7)
    )
    np.testing.assert_array_equal(hist.modes, ArchesSession(spec).run().modes)


# -- suggest_gated_capacity (dynamic capacity provisioning) --------------------


def _history_with_modes(modes: np.ndarray) -> BatchedRunHistory:
    return BatchedRunHistory(modes=np.asarray(modes, np.int32), kpms={},
                             outputs={})


def test_suggest_gated_capacity_quantiles():
    # per-slot AI demand: 0, 1, 3, 2 of 4 UEs
    modes = np.ones((4, 4), np.int32)
    modes[1, :1] = 0
    modes[2, :3] = 0
    modes[3, :2] = 0
    hist = _history_with_modes(modes)
    assert suggest_gated_capacity(hist) == 3  # peak demand
    assert suggest_gated_capacity(hist, quantile=0.5) == 2
    assert suggest_gated_capacity(hist, headroom=2) == 4  # clamped to n_ues
    assert suggest_gated_capacity(_history_with_modes(np.ones((3, 2)))) == 0
    with pytest.raises(ValueError, match="quantile"):
        suggest_gated_capacity(hist, quantile=1.5)


def test_suggest_gated_capacity_sharded_buildable():
    """Sharded suggestions must survive ``per_shard_capacity`` validation:
    compaction is shard-local, so the suggestion floors at one slot per
    shard and always splits evenly (the satellite-1 regression)."""
    from repro.core.topology import per_shard_capacity

    # zero demand used to suggest 0, which a sharded engine cannot build
    for n_shards in (2, 4):
        cap = suggest_gated_capacity(
            _history_with_modes(np.ones((5, 8), np.int32)), n_shards=n_shards
        )
        assert cap == n_shards
        assert per_shard_capacity(cap, n_shards) == 1
    # non-uniform demand: the worst shard sizes the whole campaign
    modes = np.ones((4, 8), np.int32)
    modes[:, 4:7] = 0  # shard 1 (UEs 4..7) peaks at 3; shard 0 at 0
    cap = suggest_gated_capacity(_history_with_modes(modes), n_shards=2)
    assert cap == 6 and per_shard_capacity(cap, 2) == 3
    # the n_ues clamp keeps divisibility (n_ues is a shard multiple)
    cap = suggest_gated_capacity(
        _history_with_modes(modes), n_shards=2, headroom=10
    )
    assert cap == 8 and per_shard_capacity(cap, 2) == 4
    # unsharded semantics unchanged: zero demand still suggests 0
    assert suggest_gated_capacity(
        _history_with_modes(np.ones((3, 4), np.int32))
    ) == 0
    with pytest.raises(ValueError, match="divide"):
        suggest_gated_capacity(_history_with_modes(modes), n_shards=3)


def test_suggest_gated_capacity_sharded_never_unbuildable():
    """Property sweep: every (demand, quantile, headroom, shards) draw
    yields a capacity ``per_shard_capacity`` accepts."""
    from repro.core.topology import per_shard_capacity

    rng = np.random.default_rng(0)
    for _ in range(50):
        n_shards = int(rng.choice([1, 2, 4, 8]))
        n_ues = n_shards * int(rng.integers(1, 4))
        modes = rng.integers(0, 2, size=(6, n_ues)).astype(np.int32)
        cap = suggest_gated_capacity(
            _history_with_modes(modes),
            quantile=float(rng.uniform(0.0, 1.0)),
            headroom=int(rng.integers(0, 3)),
            n_shards=n_shards,
        )
        assert 0 <= cap <= n_ues
        if n_shards > 1:
            per_shard_capacity(cap, n_shards)  # must not raise


def _history_with_residency(modes, attached) -> BatchedRunHistory:
    return BatchedRunHistory(
        modes=np.asarray(modes, np.int32), kpms={}, outputs={},
        attached=np.asarray(attached, bool),
    )


def test_suggest_gated_capacity_counts_resident_demand_only():
    """Streaming histories size from *resident* AI demand: a detached
    slot-UE's declared mode claims no gated capacity, so a churn campaign
    over an id universe wider than the bank is sized from concurrent
    residency, not the full stable-id axis."""
    modes = np.zeros((4, 6), np.int32)  # every id declares AI ...
    attached = np.zeros((4, 6), bool)
    attached[:, :2] = True  # ... but only 2 are resident
    attached[2, 2] = True  # one slot peaks at 3 residents
    hist = _history_with_residency(modes, attached)
    assert suggest_gated_capacity(hist) == 3
    assert suggest_gated_capacity(hist, quantile=0.5) == 2
    # an all-detached campaign claims no gated capacity at all
    assert suggest_gated_capacity(
        _history_with_residency(modes, np.zeros((4, 6), bool))
    ) == 0
    # plain histories (attached is None) keep the original semantics
    assert suggest_gated_capacity(_history_with_modes(modes)) == 6


def test_suggest_gated_capacity_resident_demand_property_sweep():
    """Property sweep beside the shard-divisibility one: masking by
    residency never raises the suggestion, stays buildable under shards,
    and at (quantile=1, headroom=0, n_shards=1) equals the realized peak
    resident AI demand exactly."""
    from repro.core.topology import per_shard_capacity

    rng = np.random.default_rng(1)
    for _ in range(50):
        n_shards = int(rng.choice([1, 2, 4, 8]))
        n_ues = n_shards * int(rng.integers(1, 4))
        modes = rng.integers(0, 2, size=(6, n_ues)).astype(np.int32)
        attached = rng.random((6, n_ues)) < 0.6
        kw = dict(
            quantile=float(rng.uniform(0.0, 1.0)),
            headroom=int(rng.integers(0, 3)),
            n_shards=n_shards,
        )
        cap_resident = suggest_gated_capacity(
            _history_with_residency(modes, attached), **kw
        )
        cap_plain = suggest_gated_capacity(_history_with_modes(modes), **kw)
        assert cap_resident <= cap_plain
        assert 0 <= cap_resident <= n_ues
        if n_shards > 1:
            per_shard_capacity(cap_resident, n_shards)  # must not raise
        peak = suggest_gated_capacity(
            _history_with_residency(modes, attached)
        )
        assert peak == int(((modes == 0) & attached).sum(axis=1).max())


def test_legacy_shim_defaults_match_from_spec(legacy_engine):
    """The deprecation shim must forward kwargs equivalently to
    ``from_spec``: the same resolved default/fail-safe modes (from the
    switch config, not a hard-coded 1) and bitwise-equal trajectories —
    warning exactly once."""
    spec = CampaignSpec(
        path="closed_loop", scenario="good_poor_good",
        scenario_args=POOR_ARGS, n_ues=N_UES, n_slots=6, seed=7,
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=18.0, hysteresis=2.0),),
        # default_mode=0 makes the forwarding observable: a shim that
        # hard-codes mode 1 diverges from from_spec here
        switch=SwitchSpec(window_slots=2, backend="ref", default_mode=0),
    )
    session = ArchesSession(spec)
    sw_cfg = spec.switch.to_config(spec.feature_names)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = ArchesRuntime(
            closed_loop=True, engine=legacy_engine,
            device_policy=session.device_policy, switch_config=sw_cfg,
        )
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, [str(w.message) for w in dep]
    via_spec = ArchesRuntime.from_spec(
        spec, engine=legacy_engine, device_policy=session.device_policy
    )
    assert shim.default_mode == via_spec.default_mode == 0
    assert shim.fail_safe_mode == via_spec.fail_safe_mode == 0
    h1 = shim.run_batched(
        SCHED, n_slots=6, n_ues=N_UES, key=jax.random.PRNGKey(7)
    )
    h2 = via_spec.run_batched(
        SCHED, n_slots=6, n_ues=N_UES, key=jax.random.PRNGKey(7)
    )
    np.testing.assert_array_equal(h1.modes, h2.modes)
    np.testing.assert_array_equal(h1.decisions, h2.decisions)
    np.testing.assert_array_equal(h1.n_switches, h2.n_switches)
    # host-loop construction keeps the historical mode-1 default
    host = ArchesRuntime(lambda m, c, s: (c, None, {}))
    assert host.default_mode == 1 and host.fail_safe_mode == 1


# -- fused / bf16 bank specs ---------------------------------------------------


def test_fused_session_matches_unfused_bitwise(legacy_params):
    modes = np.ones((N_SLOTS, N_UES), np.int32)
    modes[:, 0] = 0
    mk = lambda fused: restored(CampaignSpec(
        path="gated", scenario="good_poor_good", scenario_args=POOR_ARGS,
        n_ues=N_UES, n_slots=N_SLOTS, seed=3,
        modes=tuple(map(tuple, modes)),
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=1,
                            fused=fused),
    ))
    plain = ArchesSession(mk(False), ai_params=legacy_params).run()
    fused = ArchesSession(mk(True), ai_params=legacy_params).run()
    for k in plain.kpms:
        np.testing.assert_array_equal(plain.kpms[k], fused.kpms[k])
    for k in plain.outputs:
        np.testing.assert_array_equal(plain.outputs[k], fused.outputs[k])


def test_bf16_audited_session_runs_and_records(legacy_params):
    # The audit scores the expert output against the MMSE fail-safe, so the
    # NMSE at a given slot is data-dependent (here ~1-10 on the poor window):
    # a generous threshold must stay quiet, a vanishing one must trip every
    # AI-served slot-UE.
    modes = np.ones((6, N_UES), np.int32)
    modes[:, 0] = 0
    mk = lambda thr: restored(CampaignSpec(
        path="gated", scenario="good_poor_good", scenario_args=POOR_ARGS,
        n_ues=N_UES, n_slots=6, seed=3, modes=tuple(map(tuple, modes)),
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=1,
                            fused=True, dtype="bfloat16",
                            audit_nmse_threshold=thr),
    ))
    hist = ArchesSession(mk(100.0), ai_params=legacy_params).run()
    assert "audit_tripped" in hist.outputs
    assert hist.audit_tripped_slot_ues == 0  # generous threshold: quiet
    assert hist.overflow_slot_ues == 0
    strict = ArchesSession(mk(1e-12), ai_params=legacy_params).run()
    assert strict.audit_tripped_slot_ues == 6  # every AI-served slot-UE


def test_bank_spec_validates_fused_and_dtype():
    with pytest.raises(ValueError, match="fused"):
        ExpertBankSpec(fused=True)  # concurrent bank cannot fuse
    with pytest.raises(ValueError, match="dtype"):
        ExpertBankSpec(dtype="fp8")
    with pytest.raises(ValueError, match="gated"):
        ExpertBankSpec(audit_nmse_threshold=0.5)
    with pytest.raises(ValueError, match="> 0"):
        ExpertBankSpec(execution_mode="gated", audit_nmse_threshold=-1.0)


def test_suggest_gated_capacity_closes_overflow(legacy_params):
    """An under-provisioned campaign's own telemetry suggests the capacity
    that eliminates its overflow on a rerun."""
    modes = np.ones((4, 3), np.int32)
    modes[2, :3] = 0  # peak demand: all 3 UEs on AI at slot 2
    modes[3, :2] = 0

    def run_with(capacity):
        eng = BatchedPuschPipeline(
            CFG, legacy_params, net=NET,
            execution_mode=ExecutionMode.GATED, gated_capacity=capacity,
        )
        _, traj = eng.run(SCHED, modes, n_slots=4, n_ues=3,
                          key=jax.random.PRNGKey(0))
        return BatchedRunHistory.from_trajectory(modes, traj)

    starved = run_with(1)
    assert starved.overflow_slot_ues == 3  # 2 at slot 2, 1 at slot 3
    cap = suggest_gated_capacity(starved)
    assert cap == 3
    assert run_with(cap).overflow_slot_ues == 0


# -- spec-hash completeness (PR 8 satellite) -----------------------------------
#
# Every dataclass field of ``CampaignSpec`` and its sub-specs must flow
# into the canonical JSON and therefore perturb ``spec_hash`` — a field
# that doesn't is silent provenance loss (two different campaigns sharing
# one hash).  The perturbation table gives each field one *valid*
# alternate value; a new field without a table entry fails loudly.


def _hash_completeness_case():
    import dataclasses as dc

    from repro.core.faults import FaultSpec
    from repro.core.session import ExpertBankSpec, SwitchSpec
    from repro.core.streaming import ChurnSchedule
    from repro.core.topology import TopologySpec

    baseline = CampaignSpec(
        path="closed_loop", scenario="good_poor_good",
        scenario_args=(), n_ues=4, n_slots=8, n_prb=6,
        seed=0, modes=1,
        bank=ExpertBankSpec(execution_mode="gated", gated_capacity=2),
        policies=(PolicySpec(kind="threshold", feature="snr"),),
        policy_assignment=None,
        switch=SwitchSpec(window_slots=2, backend="ref"),
        topology=TopologySpec(n_cells=2),
        churn=ChurnSchedule(n_ue_ids=6, segment_slots=4, initial=(0, 1, 3)),
        faults=FaultSpec(decision_outages=((2, 4),), seed=3,
                         corruption_spans=((1, 3),),
                         telemetry_spans=((5, 6),)),
    )
    alternates = {
        ("CampaignSpec", "path"): "batched",
        ("CampaignSpec", "scenario"): "good",
        ("CampaignSpec", "scenario_args"): (("poor_start", 3),),
        ("CampaignSpec", "n_ues"): 6,
        ("CampaignSpec", "n_slots"): 12,
        ("CampaignSpec", "n_prb"): 12,
        ("CampaignSpec", "seed"): 1,
        ("CampaignSpec", "modes"): 0,
        ("CampaignSpec", "bank"): ExpertBankSpec(),
        ("CampaignSpec", "policies"): (
            PolicySpec(kind="threshold", feature="snr", threshold=5.0),
        ),
        ("CampaignSpec", "policy_assignment"): (0, 0, 0, 0),
        ("CampaignSpec", "switch"): SwitchSpec(window_slots=4,
                                               backend="ref"),
        ("CampaignSpec", "feature_names"): tuple(reversed(SELECTED_KPMS)),
        ("CampaignSpec", "rho"): (0.0, 0.25, 0.5, 0.75),
        ("CampaignSpec", "topology"): TopologySpec(n_cells=2, coupling=0.3),
        ("CampaignSpec", "churn"): ChurnSchedule(
            n_ue_ids=6, segment_slots=4, initial=(0, 1)),
        ("CampaignSpec", "faults"): FaultSpec(seed=9),
        ("ExpertBankSpec", "execution_mode"): "concurrent",
        ("ExpertBankSpec", "gated_capacity"): 3,
        ("ExpertBankSpec", "use_pallas_switch"): False,
        ("ExpertBankSpec", "channels"): 4,
        ("ExpertBankSpec", "n_res_blocks"): 2,
        ("ExpertBankSpec", "params_seed"): 1,
        ("ExpertBankSpec", "fused"): True,
        ("ExpertBankSpec", "dtype"): "bfloat16",
        ("ExpertBankSpec", "audit_nmse_threshold"): 0.5,
        ("PolicySpec", "kind"): "tree",
        ("PolicySpec", "depth"): 3,
        ("PolicySpec", "train_slots"): 4,
        ("PolicySpec", "train_ues"): 3,
        ("PolicySpec", "train_scenario"): "good",
        ("PolicySpec", "train_scenario_args"): (("poor_start", 2),),
        ("PolicySpec", "feature"): "rsrp",
        ("PolicySpec", "threshold"): 7.5,
        ("PolicySpec", "hysteresis"): 1.0,
        ("PolicySpec", "mode_above"): 0,
        ("PolicySpec", "mode_below"): 1,
        ("SwitchSpec", "window_slots"): 4,
        ("SwitchSpec", "hysteresis_slots"): 2,
        ("SwitchSpec", "period_slots"): 2,
        ("SwitchSpec", "default_mode"): 0,
        ("SwitchSpec", "backend"): "auto",
        ("SwitchSpec", "ttl_slots"): 8,
        ("TopologySpec", "n_cells"): 1,
        ("TopologySpec", "n_shards"): 1,
        ("TopologySpec", "coupling"): 0.25,
        ("TopologySpec", "cell_noise_offsets_db"): (0.0, 1.0),
        ("TopologySpec", "cell_inr_offsets_db"): (0.0, 1.0),
        ("ChurnSchedule", "n_ue_ids"): 4,
        ("ChurnSchedule", "segment_slots"): 2,
        ("ChurnSchedule", "initial"): (0, 1),
        ("ChurnSchedule", "events"): ((4, 4, "attach"),),
        ("FaultSpec", "seed"): 4,
        ("FaultSpec", "decision_outages"): ((2, 5),),
        ("FaultSpec", "decision_drop_prob"): 0.2,
        ("FaultSpec", "corruption_spans"): ((1, 4),),
        ("FaultSpec", "corruption_kind"): "inf",
        ("FaultSpec", "corruption_scale"): 10.0,
        ("FaultSpec", "corruption_prob"): 0.5,
        ("FaultSpec", "telemetry_spans"): ((5, 7),),
        ("FaultSpec", "telemetry_drop_prob"): 0.3,
        ("FaultSpec", "breaker_trips"): 4,
        ("FaultSpec", "breaker_window"): 5,
        ("FaultSpec", "breaker_cooldown"): 8,
    }
    return baseline, alternates


def test_spec_hash_every_field_perturbs():
    import dataclasses as dc

    baseline, alternates = _hash_completeness_case()
    h0 = spec_hash(baseline)
    sub_attr = {"ExpertBankSpec": "bank", "PolicySpec": None,
                "SwitchSpec": "switch", "TopologySpec": "topology",
                "ChurnSchedule": "churn", "FaultSpec": "faults"}

    def perturbed_spec(owner, field_name, alt):
        if (owner, field_name) == ("CampaignSpec", "policy_assignment"):
            # per-UE assignment is rejected under churn: perturb against
            # a churn-free variant of the baseline instead
            ref = dc.replace(baseline, churn=None)
            spec2 = dc.replace(ref, policy_assignment=alt)
            assert spec_hash(spec2) != spec_hash(ref), (owner, field_name)
            return spec2
        if owner == "CampaignSpec":
            return dc.replace(baseline, **{field_name: alt})
        if owner == "PolicySpec":
            pol = dc.replace(baseline.policies[0], **{field_name: alt})
            return dc.replace(baseline, policies=(pol,))
        attr = sub_attr[owner]
        sub = dc.replace(getattr(baseline, attr), **{field_name: alt})
        return dc.replace(baseline, **{attr: sub})

    from repro.core.faults import FaultSpec
    from repro.core.session import ExpertBankSpec, SwitchSpec
    from repro.core.streaming import ChurnSchedule
    from repro.core.topology import TopologySpec

    for cls in (CampaignSpec, ExpertBankSpec, PolicySpec, SwitchSpec,
                TopologySpec, ChurnSchedule, FaultSpec):
        for f in dc.fields(cls):
            key = (cls.__name__, f.name)
            assert key in alternates, f"no perturbation case for {key}"
            spec2 = perturbed_spec(cls.__name__, f.name, alternates[key])
            # a valid alternate must actually differ from the baseline
            assert spec2 != baseline, key
            assert spec_hash(spec2) != h0, (
                f"{key} does not perturb spec_hash: provenance loss"
            )


def test_spec_hash_canonical_dict_is_field_complete():
    """Structural half of the same guarantee: the canonical dict feeding
    ``spec_hash`` carries every field of every (sub-)spec dataclass."""
    import dataclasses as dc

    baseline, _ = _hash_completeness_case()
    d = baseline.to_dict()
    assert set(d) == {f.name for f in dc.fields(CampaignSpec)}
    for key, obj in (("bank", baseline.bank), ("switch", baseline.switch),
                     ("topology", baseline.topology),
                     ("churn", baseline.churn),
                     ("faults", baseline.faults)):
        assert set(d[key]) == {f.name for f in dc.fields(type(obj))}, key
    assert set(d["policies"][0]) == {
        f.name for f in dc.fields(PolicySpec)
    }
