"""Switching policies: tree trainer, Table-1 metrics, threshold gating."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import (
    DecisionTreePolicy,
    ThresholdPolicy,
    classification_metrics,
    fit_decision_tree,
)


def test_depth1_matches_brute_force():
    """Depth-1 tree must find the single best Gini threshold."""
    rng = np.random.default_rng(101)  # explicit: tree fitting must be deterministic
    x = rng.normal(size=(200, 3)).astype(np.float32)
    y = (x[:, 1] > 0.37).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=1)
    assert tree.feature[0] == 1
    assert abs(tree.threshold[0] - 0.37) < 0.2
    pol = DecisionTreePolicy(tree, ["a", "b", "c"])
    pred = np.asarray(pol.batch(jnp.asarray(x)))
    assert (pred == y).mean() == 1.0


def test_depth2_xor_structure():
    """Depth-2 tree separates an axis-aligned 2-split problem perfectly."""
    rng = np.random.default_rng(102)
    x = rng.uniform(-1, 1, size=(500, 2)).astype(np.float32)
    y = ((x[:, 0] > 0) & (x[:, 1] > 0)).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=2)
    pol = DecisionTreePolicy(tree, ["a", "b"])
    pred = np.asarray(pol.batch(jnp.asarray(x)))
    assert (pred == y).mean() >= 0.99


def test_importances_normalized():
    rng = np.random.default_rng(103)
    x = rng.normal(size=(300, 4)).astype(np.float32)
    y = (x[:, 2] > 0).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=2)
    assert abs(tree.importances.sum() - 1.0) < 1e-5
    assert tree.importances.argmax() == 2


def test_pure_node_stops_splitting():
    x = np.ones((50, 2), np.float32)
    y = np.zeros(50, np.int32)
    tree = fit_decision_tree(x, y, depth=2)
    pol = DecisionTreePolicy(tree, ["a", "b"])
    assert int(pol(jnp.asarray([1.0, 1.0]))) == 0


def test_classification_metrics_hand_check():
    y_true = np.array([0, 0, 0, 1, 1, 1, 1, 1])
    y_pred = np.array([0, 0, 1, 1, 1, 1, 1, 0])
    m = classification_metrics(y_true, y_pred)
    # positive class is 0 (AI): tp=2 fp=1 fn=1 tn=4
    assert m["accuracy"] == pytest.approx(6 / 8)
    assert m["precision"] == pytest.approx(2 / 3)
    assert m["recall"] == pytest.approx(2 / 3)
    assert m["specificity"] == pytest.approx(4 / 5)
    assert m["f1"] == pytest.approx(2 / 3)


def test_tree_beats_majority_baseline_property():
    """Property: fitted tree's train accuracy >= majority-class baseline."""
    rng = np.random.default_rng(104)
    for trial in range(10):
        n = int(rng.integers(40, 300))
        f = int(rng.integers(1, 8))
        x = rng.normal(size=(n, f)).astype(np.float32)
        y = rng.integers(0, 2, size=n).astype(np.int32)
        tree = fit_decision_tree(x, y, depth=2)
        pol = DecisionTreePolicy(tree, [f"f{i}" for i in range(f)])
        pred = np.asarray(pol.batch(jnp.asarray(x)))
        acc = (pred == y).mean()
        baseline = max(y.mean(), 1 - y.mean())
        assert acc >= baseline - 1e-9, f"trial {trial}: {acc} < {baseline}"


def test_single_equals_batch_property():
    rng = np.random.default_rng(105)
    x = rng.normal(size=(100, 6)).astype(np.float32)
    y = (x[:, 0] * x[:, 3] > 0).astype(np.int32)
    tree = fit_decision_tree(x, y, depth=3)
    pol = DecisionTreePolicy(tree, [f"f{i}" for i in range(6)])
    batch = np.asarray(pol.batch(jnp.asarray(x)))
    single = np.asarray([int(pol(jnp.asarray(v))) for v in x])
    np.testing.assert_array_equal(batch, single)


def test_threshold_policy_hysteresis():
    pol = ThresholdPolicy(feature_idx=0, threshold=5.0, hysteresis=1.0)
    # above band -> mode_above
    assert int(pol(jnp.asarray([6.5]), prev_mode=0)) == 1
    # below band -> mode_below
    assert int(pol(jnp.asarray([3.5]), prev_mode=1)) == 0
    # inside band -> keep previous (no flapping)
    assert int(pol(jnp.asarray([5.3]), prev_mode=0)) == 0
    assert int(pol(jnp.asarray([4.8]), prev_mode=1)) == 1


def test_feature_name_mismatch():
    tree = fit_decision_tree(
        np.zeros((4, 2), np.float32), np.array([0, 0, 1, 1]), depth=1
    )
    with pytest.raises(ValueError):
        DecisionTreePolicy(tree, ["only_one"])
