"""In-scan closed-loop switching == host E3/dApp loop (the equivalence suite).

The paper's closed loop makes its decision host-side (dApp) and commits it at
the next slot boundary; our scan engine compiles the same policy *into* the
slot loop.  These tests prove the two are the same policy:

* device-decided mode trajectories bitwise-match a host replay feeding the
  identical KPM windows through ``DecisionTreePolicy`` slot by slot, per UE,
  including hysteresis state and switch counts;
* the Pallas ``tree_infer`` backend and the literal-walk ref backend decide
  identically inside the scan;
* switch-register/hysteresis semantics hold as *properties*: a decision at
  slot ``t`` is never applied before ``t+1``, and oscillating telemetry
  cannot flip modes faster than the hysteresis window;
* the whole closed-loop slot loop stays one compiled ``lax.scan`` with no
  per-slot host callbacks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.closed_loop import (
    DeviceThresholdPolicy,
    SwitchConfig,
    host_replay_closed_loop,
    init_device_switch,
    switch_boundary,
    switch_update,
)
from repro.core.policy import ThresholdPolicy, profile_and_fit_tree
from repro.core.telemetry import SELECTED_KPMS, trajectory_kpm_matrix
from repro.phy.ai_estimator import AiEstimatorConfig, init_params
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import good_poor_good_schedule

CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=8, n_res_blocks=1)
N_SLOTS, N_UES = 18, 3
SCHED = good_poor_good_schedule(poor_start=6, poor_end=12)


@pytest.fixture(scope="module")
def engine():
    params = init_params(jax.random.PRNGKey(0), CFG, NET)
    return BatchedPuschPipeline(CFG, params, net=NET)


@pytest.fixture(scope="module")
def tree_policy(engine):
    """Depth-2 tree trained on profiled telemetry from both experts."""
    return profile_and_fit_tree(engine, SCHED, n_slots=N_SLOTS, n_ues=2)


def _campaign(engine, policy, **cfg_kw):
    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, **cfg_kw)
    device = policy.to_device()
    _, sw, traj = engine.run_closed_loop(
        SCHED, device, sw_cfg,
        n_slots=N_SLOTS, n_ues=N_UES, key=jax.random.PRNGKey(7),
    )
    return sw_cfg, sw, jax.tree.map(np.asarray, traj)


# -- device == host replay (the paper's loop equivalence) ----------------------


@pytest.mark.parametrize("hysteresis_slots", [1, 2])
def test_device_matches_host_replay(engine, tree_policy, hysteresis_slots):
    """Per-UE device mode trajectories bitwise-match the host replay.

    The replay feeds the *same* telemetry (the trajectory's KPM leaves,
    stacked in feature order) through ``DecisionTreePolicy`` — the literal
    host tree walk — slot by slot with identical window/hysteresis/register
    bookkeeping.  Covers hysteresis state: with ``hysteresis_slots=2`` the
    trajectories differ from the h=1 run but still match their own replay.
    """
    sw_cfg, sw, traj = _campaign(
        engine, tree_policy, window_slots=4,
        hysteresis_slots=hysteresis_slots, backend="ref",
    )
    feats = np.asarray(trajectory_kpm_matrix(traj["kpms"], SELECTED_KPMS))
    replay = host_replay_closed_loop(tree_policy, feats, sw_cfg)
    np.testing.assert_array_equal(traj["active_mode"], replay["active_mode"])
    np.testing.assert_array_equal(traj["raw_decision"], replay["raw_decision"])
    np.testing.assert_array_equal(traj["pending_mode"], replay["pending_mode"])
    np.testing.assert_array_equal(np.asarray(sw.n_switches), replay["n_switches"])
    # non-vacuous: the policy actually switched during the poor phase
    assert replay["n_switches"].sum() > 0
    assert (traj["active_mode"] == 0).any() and (traj["active_mode"] == 1).any()


@pytest.mark.parametrize("period_slots,hysteresis_slots", [(2, 1), (5, 1), (3, 2)])
def test_periodic_decisions_match_host_replay(
    engine, tree_policy, period_slots, hysteresis_slots
):
    """``period_slots`` holds the register between decision slots, and the
    host replay mirrors the same hold logic bitwise (the dApp's decision
    periodicity, now honored inside the scan).  Hold slots freeze the
    hysteresis streak rather than resetting it, so periodicity composes
    with ``hysteresis_slots > 1`` (the (3, 2) case would deadlock on MMSE
    forever if a hold slot counted as an agreeing decision)."""
    sw_cfg, sw, traj = _campaign(
        engine, tree_policy, window_slots=2, period_slots=period_slots,
        hysteresis_slots=hysteresis_slots, backend="ref",
    )
    feats = np.asarray(trajectory_kpm_matrix(traj["kpms"], SELECTED_KPMS))
    replay = host_replay_closed_loop(tree_policy, feats, sw_cfg)
    np.testing.assert_array_equal(traj["active_mode"], replay["active_mode"])
    np.testing.assert_array_equal(traj["raw_decision"], replay["raw_decision"])
    np.testing.assert_array_equal(traj["pending_mode"], replay["pending_mode"])
    np.testing.assert_array_equal(np.asarray(sw.n_switches), replay["n_switches"])
    # the register may only move on decision slots (slot % period == 0)
    pend = traj["pending_mode"]
    changed = (pend[1:] != pend[:-1]).any(axis=1)
    hold = (np.arange(1, N_SLOTS) % period_slots) != 0
    assert not changed[hold].any(), "register rewritten on a hold slot"
    # non-vacuous: the periodic policy still reacts to the poor phase
    assert replay["n_switches"].sum() > 0


def test_periodic_decisions_differ_from_every_slot(engine, tree_policy):
    """period_slots must actually change behaviour (lagged reactions)."""
    _, _, every = _campaign(engine, tree_policy, window_slots=2, backend="ref")
    _, _, held = _campaign(
        engine, tree_policy, window_slots=2, period_slots=5, backend="ref"
    )
    assert not np.array_equal(every["active_mode"], held["active_mode"])


def test_closed_loop_tracks_conditions(engine, tree_policy):
    """Device-decided modes select AI (0) in the poor phase, MMSE before it."""
    _, _, traj = _campaign(engine, tree_policy, window_slots=2)
    modes = traj["active_mode"]
    # decisions lag the phase edge by the window + one boundary slot
    assert (modes[:4] == 1).all(), "good#1 phase should stay on MMSE"
    assert (modes[9:12] == 0).mean() > 0.5, "poor phase should move to AI"


def test_threshold_policy_device_matches_host(engine):
    """The threshold-gate export (prev-mode keep-band) replays bitwise too."""
    policy = ThresholdPolicy(
        feature_idx=SELECTED_KPMS.index("snr"), threshold=18.0, hysteresis=2.0
    )
    sw_cfg, sw, traj = _campaign(engine, policy, window_slots=3)
    feats = np.asarray(trajectory_kpm_matrix(traj["kpms"], SELECTED_KPMS))
    replay = host_replay_closed_loop(policy, feats, sw_cfg)
    np.testing.assert_array_equal(traj["active_mode"], replay["active_mode"])
    np.testing.assert_array_equal(traj["raw_decision"], replay["raw_decision"])
    np.testing.assert_array_equal(np.asarray(sw.n_switches), replay["n_switches"])


def test_pallas_backend_matches_ref_in_scan(engine, tree_policy):
    """The MXU tree kernel and the literal walk decide identically in-scan."""
    _, _, ref = _campaign(engine, tree_policy, window_slots=4, backend="ref")
    _, _, pal = _campaign(engine, tree_policy, window_slots=4, backend="pallas")
    np.testing.assert_array_equal(ref["active_mode"], pal["active_mode"])
    np.testing.assert_array_equal(ref["raw_decision"], pal["raw_decision"])


def test_scan_equals_python_loop(engine, tree_policy):
    """The compiled scan and the per-slot jitted loop are the same program."""
    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, window_slots=3)
    device = tree_policy.to_device()
    kw = dict(n_slots=10, n_ues=2, key=jax.random.PRNGKey(5))
    _, sw_a, ta = engine.run_closed_loop(SCHED, device, sw_cfg, use_scan=True, **kw)
    _, sw_b, tb = engine.run_closed_loop(SCHED, device, sw_cfg, use_scan=False, **kw)
    ta, tb = jax.tree.map(np.asarray, ta), jax.tree.map(np.asarray, tb)
    np.testing.assert_array_equal(ta["active_mode"], tb["active_mode"])
    np.testing.assert_array_equal(ta["raw_decision"], tb["raw_decision"])
    np.testing.assert_array_equal(
        np.asarray(sw_a.n_switches), np.asarray(sw_b.n_switches)
    )
    np.testing.assert_allclose(
        ta["kpms"]["aerial"]["sinr"], tb["kpms"]["aerial"]["sinr"],
        rtol=1e-5, atol=1e-6,
    )


def test_no_host_callbacks_in_scan(engine, tree_policy):
    """The whole closed loop compiles as lax.scan — no per-slot host hops."""
    from repro.phy.channel import channel_params_schedule
    from repro.phy.pipeline import init_device_link

    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, window_slots=3)
    device = tree_policy.to_device()
    n_slots, n_ues = 6, 2
    profile, params = channel_params_schedule(CFG, SCHED, n_slots)
    link0 = init_device_link(n_ues)
    sw0 = init_device_switch(n_ues, len(SELECTED_KPMS), sw_cfg)
    ue_keys = jax.random.split(jax.random.PRNGKey(1), n_ues)
    jaxpr = jax.make_jaxpr(
        lambda l, s, k, p, d: engine._run_closed_scan(
            profile, sw_cfg, l, s, k, p, d
        )
    )(link0, sw0, ue_keys, params, device)
    txt = str(jaxpr)
    assert "scan[" in txt
    for prim in ("pure_callback", "io_callback", "python_callback", "callback["):
        assert prim not in txt, f"host callback {prim} inside the slot scan"


# -- runtime integration -------------------------------------------------------


def test_runtime_closed_loop_records_device_modes(engine, tree_policy):
    """ArchesRuntime(closed_loop=True) lands device decisions in the history."""
    from repro.core.e3 import E3Agent, E3Subscription
    from repro.core.runtime import ArchesRuntime

    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS, window_slots=4)
    device = tree_policy.to_device()
    agent = E3Agent()
    seen = []
    agent.subscribe(E3Subscription(callback=seen.append))
    runtime = ArchesRuntime(
        agent=agent, closed_loop=True, engine=engine,
        device_policy=device, switch_config=sw_cfg,
    )
    hist = runtime.run_batched(
        SCHED, n_slots=N_SLOTS, n_ues=N_UES,
        key=jax.random.PRNGKey(7), replay_telemetry=True,
    )
    # the history's modes are the device-decided active modes of the scan
    _, sw, traj = engine.run_closed_loop(
        SCHED, device, sw_cfg,
        n_slots=N_SLOTS, n_ues=N_UES, key=jax.random.PRNGKey(7),
    )
    np.testing.assert_array_equal(hist.modes, np.asarray(traj["active_mode"]))
    np.testing.assert_array_equal(
        hist.decisions, np.asarray(traj["raw_decision"])
    )
    np.testing.assert_array_equal(hist.n_switches, np.asarray(sw.n_switches))
    assert hist.per_ue(0)[0].active_mode == 1  # cold start on the default
    assert len(seen) == N_SLOTS * 2  # aerial + oai replayed post-run


def test_runtime_closed_loop_validation(engine):
    from repro.core.runtime import ArchesRuntime

    with pytest.raises(ValueError, match="closed_loop"):
        ArchesRuntime(closed_loop=True)
    rt = ArchesRuntime(slot_fn=lambda *a: None, agent=None)
    with pytest.raises(RuntimeError, match="closed_loop"):
        rt.run_batched(SCHED, n_slots=2, n_ues=1)


# -- switch-register / hysteresis properties (no pipeline) ---------------------


def _gate(threshold=0.0):
    """Single-feature gate: x > thr -> mode 1, else mode 0 (no keep-band)."""
    return DeviceThresholdPolicy(
        feature_idx=jnp.int32(0),
        lo=jnp.float32(threshold),
        hi=jnp.float32(threshold),
        mode_above=jnp.int32(1),
        mode_below=jnp.int32(0),
    )


def _drive(feature_stream, *, hysteresis_slots, default_mode=1, window_slots=1):
    """Run the register state machine over a synthetic per-slot feature.

    ``feature_stream``: (S,) — one scalar KPM, one UE.  Returns per-slot
    (active, raw, pending) int arrays.
    """
    cfg = SwitchConfig(
        feature_names=("f",),
        window_slots=window_slots,
        hysteresis_slots=hysteresis_slots,
        default_mode=default_mode,
    )
    state = init_device_switch(1, 1, cfg)
    policy = _gate()
    active, raw_h, pending = [], [], []
    for v in feature_stream:
        active.append(int(state.active_mode[0]))
        state, raw = switch_update(
            state, jnp.asarray([[v]], jnp.float32), policy, cfg
        )
        raw_h.append(int(raw[0]))
        pending.append(int(state.pending_mode[0]))
        state = switch_boundary(state)
    return (
        np.asarray(active),
        np.asarray(raw_h),
        np.asarray(pending),
        int(state.n_switches[0]),
    )


def test_decision_never_applied_before_next_slot(rng):
    """Property: active mode at slot t is the register committed before t.

    Whatever the telemetry does, slot t's decision can only surface at
    t+1 or later — the no-mid-slot-corruption contract at the boundary.
    """
    for trial in range(5):
        stream = rng.normal(size=30)
        for h in (1, 2, 3):
            active, _, pending, _ = _drive(stream, hysteresis_slots=h)
            assert active[0] == 1  # cold start: the default, no decision yet
            # active mode of slot t+1 is exactly the register after slot t
            np.testing.assert_array_equal(active[1:], pending[:-1])


def test_oscillation_cannot_beat_hysteresis_window(rng):
    """Property: alternating telemetry never flips the mode when h >= 2.

    The raw decision flips every slot, so the disagreement streak resets
    before reaching the hysteresis window — the register (and therefore the
    active mode) stays put.  With h=1 the same stream flaps maximally.
    """
    stream = np.where(np.arange(40) % 2 == 0, 5.0, -5.0)  # raw: 1,0,1,0,...
    for h in (2, 3, 5):
        active, raw, _, n_switches = _drive(stream, hysteresis_slots=h)
        assert set(np.unique(raw)) == {0, 1}  # the policy itself oscillates
        assert n_switches == 0, f"h={h} must suppress flapping"
        assert (active == 1).all()
    active, _, _, n_switches = _drive(stream, hysteresis_slots=1)
    assert n_switches > 30  # h=1: every decision commits, maximal flapping


def test_sustained_change_commits_after_exactly_h_slots():
    """A persistent condition change flips the register after h disagreeing
    decisions, and the active mode one boundary later."""
    flip_at = 10
    stream = np.where(np.arange(25) < flip_at, 5.0, -5.0)  # mode 1 -> 0
    for h in (1, 2, 4):
        active, raw, pending, n_switches = _drive(stream, hysteresis_slots=h)
        # raw flips at slot `flip_at`; the register needs h such slots
        commit_slot = flip_at + h - 1
        assert (pending[:commit_slot] == 1).all()
        assert (pending[commit_slot:] == 0).all()
        # ...and the active mode follows one slot boundary later
        assert (active[: commit_slot + 1] == 1).all()
        assert (active[commit_slot + 1 :] == 0).all()
        assert n_switches == 1


def test_window_mean_feeds_the_policy(rng):
    """window_slots > 1 decides on the rolling mean, not the instant value."""
    # one outlier inside an otherwise-high stream: with a 4-slot window the
    # mean stays above threshold and the mode never leaves 1
    stream = np.full(16, 4.0)
    stream[8] = -6.0  # instant gate would say 0; mean (4*3-6)/4 = 1.5 > 0
    active, raw, _, n_switches = _drive(
        stream, hysteresis_slots=1, window_slots=4
    )
    assert n_switches == 0 and (active == 1).all() and (raw == 1).all()
    # the same stream through a 1-slot window does react
    _, raw1, _, n1 = _drive(stream, hysteresis_slots=1, window_slots=1)
    assert raw1[8] == 0 and n1 == 2  # out and back
