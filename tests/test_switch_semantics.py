"""Slot-boundary timing semantics + fail-safe defaults (paper 2, 3.3).

These are the paper's hard invariants:
  * a decision committed during slot n is visible at slot n+1, never slot n;
  * mid-slot updates are deferred;
  * the register decays to the conventional expert after ttl slots without a
    valid decision (dApp failure);
  * the register is jit/scan-compatible (it rides the step carry).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.switch import (
    SlotSwitchState,
    commit_decision,
    init_switch_state,
    slot_boundary,
)

TTL = 4
FS = 1  # fail-safe = conventional expert


def _adv(s):
    return slot_boundary(s, fail_safe_mode=FS, ttl_slots=TTL)


def test_decision_visible_next_slot_only():
    s = init_switch_state(1)
    assert int(s.active_mode) == 1
    s = commit_decision(s, 0)  # during slot n
    assert int(s.active_mode) == 1  # still slot n: unchanged
    s = _adv(s)  # boundary -> slot n+1
    assert int(s.active_mode) == 0


def test_mid_slot_updates_deferred_last_wins():
    s = init_switch_state(1)
    s = commit_decision(s, 0)
    s = commit_decision(s, 1)
    s = commit_decision(s, 0)  # several mid-slot commits: last wins at boundary
    assert int(s.active_mode) == 1
    s = _adv(s)
    assert int(s.active_mode) == 0


def test_fail_safe_decay_after_ttl():
    s = init_switch_state(1)
    s = commit_decision(s, 0)
    s = _adv(s)
    assert int(s.active_mode) == 0
    # dApp goes silent: decay to conventional after TTL slots
    for i in range(TTL):
        s = _adv(s)
        expect = 0 if i < TTL - 1 else FS
        assert int(s.active_mode) == expect, f"slot {i}: {int(s.active_mode)}"
    # stays at fail-safe indefinitely
    s = _adv(s)
    assert int(s.active_mode) == FS


def test_recovery_after_fail_safe():
    s = init_switch_state(1)
    for _ in range(TTL + 2):
        s = _adv(s)
    assert int(s.active_mode) == FS
    s = commit_decision(s, 0)  # dApp recovers
    s = _adv(s)
    assert int(s.active_mode) == 0


def test_invalid_commit_ignored():
    s = init_switch_state(1)
    s = commit_decision(s, 0, valid=False)
    s = _adv(s)
    assert int(s.active_mode) == 1
    assert int(s.slots_since_decision) == 1  # staleness not reset by invalid


def test_n_switches_counts_transitions():
    s = init_switch_state(1)
    s = commit_decision(s, 0)
    s = _adv(s)  # 1 -> 0
    s = commit_decision(s, 0)
    s = _adv(s)  # 0 -> 0 (no switch)
    s = commit_decision(s, 1)
    s = _adv(s)  # 0 -> 1
    assert int(s.n_switches) == 2
    assert int(s.slot_index) == 3


def test_register_inside_scan():
    """The register must run inside lax.scan (it rides the jitted step)."""

    def body(s, decision):
        s = commit_decision(s, decision["mode"], decision["valid"])
        s = _adv(s)
        return s, s.active_mode

    decisions = {
        "mode": jnp.asarray([0, 0, 1, 0], jnp.int32),
        "valid": jnp.asarray([True, False, True, True]),
    }
    final, actives = jax.lax.scan(jax.jit(body), init_switch_state(1), decisions)
    np.testing.assert_array_equal(np.asarray(actives), [0, 0, 1, 0])
    assert int(final.n_switches) == 3


def test_default_mode_is_conventional_before_first_decision():
    """Fail-safe default: mode starts at the conventional expert (paper 3.2)."""
    s = init_switch_state(1)
    for _ in range(3):
        s = _adv(s)
        assert int(s.active_mode) == 1
