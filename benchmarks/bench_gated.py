"""Compaction-gated execution: compute that scales with the AI share.

The concurrent bank pays for every expert on every UE every slot — a fleet
where 1-in-16 UEs needs AI costs the same as all-AI.  The gated path runs
the folded-GEMM AI forward only on a dense capacity-K sub-batch of the UEs
that selected it (MMSE stays dense as the fail-safe baseline, the fused
scatter pass un-compacts), so the slot scan's wall time and the
executed-FLOPs proxy both track the realized expert mix — the
performance-per-watt tradeoff of the paper's Fig. 11, now as a measured
scan-engine property.

Every invocation asserts (a) the gated scan is bitwise-equal to the
concurrent scan on the same mode grid and (b) executed FLOPs at AI share 0
equal the MMSE-only cost model — so the benchmark doubles as the CI smoke
check for the gated path.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import NET, SLOT_CFG, fmt_row, get_ai_params
from repro.core.expert_bank import ExecutionMode
from repro.core.telemetry import physical_trajectory
from repro.phy.estimators import estimator_flops
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import good_poor_good_schedule


def _mode_grid(n_slots: int, n_ues: int, n_ai: int) -> np.ndarray:
    """Open-loop grid: the first ``n_ai`` UEs run AI, the rest MMSE."""
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, :n_ai] = 0
    return modes


def _timed(fn):
    out = fn()  # warm/compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return time.perf_counter() - t0, out


def run(
    n_slots: int = 60,
    n_ues: int = 16,
    shares: tuple[float, ...] = (0.0, 1.0 / 16.0, 0.5, 1.0),
) -> dict:
    """Gated vs concurrent slot scan across AI shares.

    Capacity is provisioned at the realized per-slot AI count (the
    operator's knob; overflow policy is exercised by the tests, not here),
    so provisioned == executed and the wall-time ratio isolates the
    compute-scaling win.
    """
    params, _ = get_ai_params()
    schedule = good_poor_good_schedule(
        poor_start=n_slots // 3, poor_end=2 * n_slots // 3
    )
    ue_keys = jax.random.split(jax.random.PRNGKey(123), n_ues)
    conc = BatchedPuschPipeline(SLOT_CFG, params, net=NET)
    f_mmse = estimator_flops(SLOT_CFG)
    f_ai = NET.flops(SLOT_CFG)

    print("\n== Compaction-gated expert execution ==")
    print(fmt_row("AI share", "concurrent", "gated", "speedup",
                  "exec GFLOP/slot", "overflow"))
    results: dict[str, dict] = {}
    for share in shares:
        # ceil so a nonzero share always gets >= 1 AI UE (round() would
        # collapse 1/16 of 8 UEs onto the share-0 row)
        n_ai = int(np.ceil(share * n_ues))
        modes = _mode_grid(n_slots, n_ues, n_ai)
        gated = BatchedPuschPipeline(
            SLOT_CFG, params, net=NET,
            execution_mode=ExecutionMode.GATED, gated_capacity=n_ai,
        )
        t_conc, traj_c = _timed(lambda: conc.run(
            schedule, modes, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
        )[1])
        t_gated, traj_g = _timed(lambda: gated.run(
            schedule, modes, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
        )[1])

        # contract 1: gated == concurrent, bitwise, on every physical leaf
        eq = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            physical_trajectory(traj_c), physical_trajectory(traj_g),
        )
        if not all(jax.tree.leaves(eq)):
            bad = [k for k, v in eq.items() if not all(jax.tree.leaves(v))]
            raise AssertionError(f"gated != concurrent at share {share}: {bad}")

        flops_slot = float(
            np.asarray(traj_g["executed_flops"], np.float64).sum(axis=1).mean()
        )
        expected = n_ai * f_ai + n_ues * f_mmse
        if not np.isclose(flops_slot, expected, rtol=1e-6):
            raise AssertionError(
                f"executed FLOPs {flops_slot:.4g} != cost model {expected:.4g}"
            )
        if share == 0.0 and not np.isclose(
            flops_slot, n_ues * f_mmse, rtol=1e-6
        ):
            raise AssertionError("share-0 executed FLOPs != MMSE-only model")
        overflow = int(np.asarray(traj_g["gated_overflow"]).sum())
        if overflow:
            raise AssertionError(
                f"unexpected overflow at provisioned capacity: {overflow}"
            )

        rate_c = n_slots * n_ues / t_conc
        rate_g = n_slots * n_ues / t_gated
        speedup = t_conc / t_gated
        print(fmt_row(f"{share:.4g} ({n_ai}/{n_ues})",
                      f"{rate_c:.1f} slot-UEs/s",
                      f"{rate_g:.1f} slot-UEs/s",
                      f"{speedup:.2f}x",
                      f"{flops_slot / 1e9:.3f}",
                      overflow))
        results[f"{share:.4g}"] = {
            "n_ai": n_ai,
            "concurrent_slot_ues_per_s": rate_c,
            "gated_slot_ues_per_s": rate_g,
            "speedup": speedup,
            "executed_flops_per_slot": flops_slot,
            "provisioned_flops_per_slot": gated.bank.provisioned_flops(n_ues),
            "bitwise_equal": True,
        }

    # linearity of the executed-FLOPs accounting in the AI share
    xs = np.asarray([results[k]["n_ai"] for k in results], np.float64)
    ys = np.asarray(
        [results[k]["executed_flops_per_slot"] for k in results], np.float64
    )
    lin = np.allclose(ys, n_ues * f_mmse + xs * f_ai, rtol=1e-6)
    print(fmt_row("executed-FLOPs linear in share", "yes" if lin else "NO"))
    if not lin:
        raise AssertionError("executed-FLOPs accounting is not linear")
    return {
        "n_slots": n_slots,
        "n_ues": n_ues,
        "by_share": results,
        "flops_linear_in_share": lin,
    }


if __name__ == "__main__":
    run()
