"""Compaction-gated execution: compute that scales with the AI share.

The concurrent bank pays for every expert on every UE every slot — a fleet
where 1-in-16 UEs needs AI costs the same as all-AI.  The gated path runs
the folded-GEMM AI forward only on a dense capacity-K sub-batch of the UEs
that selected it (MMSE stays dense as the fail-safe baseline, the fused
scatter pass un-compacts), so the slot scan's wall time and the
executed-FLOPs proxy both track the realized expert mix — the
performance-per-watt tradeoff of the paper's Fig. 11, now as a measured
scan-engine property.

Every invocation asserts (a) the gated scan is bitwise-equal to the
concurrent scan on the same mode grid, (b) the *fused* gated scan (one
Pallas compact -> folded-GEMM -> scatter kernel; the jnp reference path on
CPU) is bitwise-equal to the unfused triple, and (c) executed FLOPs at AI
share 0 equal the MMSE-only cost model — so the benchmark doubles as the
CI smoke check for the gated path.  A bf16-expert engine (with the in-scan
NMSE audit armed) rides along for the f32-vs-bf16 sweep; its trajectory is
*not* expected to be bitwise and the audit-trip count is recorded instead.

Off-TPU the fused engine dispatches to the jnp reference, which traces to
the *identical* XLA program as the unfused path (same jit'd scatter, same
folded GEMMs — asserted identical at the jaxpr level in
``tests/test_fused_gated.py``), so the two wall-times are one measurement:
the fused row reuses the unfused timing rather than re-measuring the same
executable and reporting scheduler jitter as a speedup.  On TPU the fused
engine runs the Pallas kernel and both are timed independently.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import NET, SLOT_CFG, fmt_row, get_ai_params
from repro.core.expert_bank import ExecutionMode
from repro.core.telemetry import physical_trajectory
from repro.phy.estimators import estimator_flops
from repro.phy.pipeline import BatchedPuschPipeline
from repro.phy.scenario import good_poor_good_schedule

#: loose divergence guard for the bf16 sweep — bf16 quantization noise is
#: NMSE ~1e-6 and the audit scores the expert against the MMSE fail-safe
#: (which it legitimately disagrees with by NMSE ~1-10 on poor channels), so
#: a wide margin keeps the zero-trip contract about precision blowups only
BF16_AUDIT_NMSE = 100.0


def _mode_grid(n_slots: int, n_ues: int, n_ai: int) -> np.ndarray:
    """Open-loop grid: the first ``n_ai`` UEs run AI, the rest MMSE."""
    modes = np.ones((n_slots, n_ues), np.int32)
    modes[:, :n_ai] = 0
    return modes


def _timed(fn, repeats: int = 1):
    out = fn()  # warm/compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    best = float("inf")
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        best = min(best, time.perf_counter() - t0)
    return best, out


def _timed_set(fns: dict, repeats: int = 1):
    """Time several closures round-robin: warm all, then interleave runs.

    Sequential per-engine timing lets slow host-load drift bias whichever
    engine runs last; interleaving (with the order reversed every other
    round, so no engine always occupies the same slot in the cycle) spreads
    the drift evenly and min-of-repeats comparisons between near-identical
    programs stay honest.
    """
    outs = {}
    for name, fn in fns.items():
        outs[name] = fn()  # warm/compile
        jax.block_until_ready(jax.tree.leaves(outs[name])[0])
    best = {name: float("inf") for name in fns}
    for r in range(max(repeats, 1)):
        order = list(fns) if r % 2 == 0 else list(fns)[::-1]
        for name in order:
            t0 = time.perf_counter()
            out = fns[name]()
            jax.block_until_ready(jax.tree.leaves(out)[0])
            best[name] = min(best[name], time.perf_counter() - t0)
    return best, outs


def run(
    n_slots: int = 60,
    n_ues: int = 16,
    shares: tuple[float, ...] = (0.0, 1.0 / 16.0, 0.25, 0.5, 1.0),
    repeats: int = 3,
) -> dict:
    """Gated vs concurrent slot scan across AI shares.

    Capacity is provisioned at the realized per-slot AI count (the
    operator's knob; overflow policy is exercised by the tests, not here),
    so provisioned == executed and the wall-time ratio isolates the
    compute-scaling win.  Each share also runs the fused hot path
    (bitwise-asserted vs unfused) and a fused-bf16 engine (audited).
    ``repeats`` takes the min of that many interleaved timed runs per
    engine.  Off-TPU the fused and unfused engines trace to the identical
    XLA program (module docstring), so the fused row shares the unfused
    timing instead of re-measuring the same executable.
    """
    params, _ = get_ai_params()
    schedule = good_poor_good_schedule(
        poor_start=n_slots // 3, poor_end=2 * n_slots // 3
    )
    ue_keys = jax.random.split(jax.random.PRNGKey(123), n_ues)
    conc = BatchedPuschPipeline(SLOT_CFG, params, net=NET)
    f_mmse = estimator_flops(SLOT_CFG)
    f_ai = NET.flops(SLOT_CFG)

    print("\n== Compaction-gated expert execution ==")
    print(fmt_row("AI share", "concurrent", "gated", "fused", "bf16",
                  "exec GFLOP/slot", "overflow"))
    results: dict[str, dict] = {}
    for share in shares:
        # ceil so a nonzero share always gets >= 1 AI UE (round() would
        # collapse 1/16 of 8 UEs onto the share-0 row)
        n_ai = int(np.ceil(share * n_ues))
        modes = _mode_grid(n_slots, n_ues, n_ai)
        gated = BatchedPuschPipeline(
            SLOT_CFG, params, net=NET,
            execution_mode=ExecutionMode.GATED, gated_capacity=n_ai,
        )
        fused = BatchedPuschPipeline(
            SLOT_CFG, params, net=NET,
            execution_mode=ExecutionMode.GATED, gated_capacity=n_ai,
            fused_gated=True,
        )
        bf16 = BatchedPuschPipeline(
            SLOT_CFG, params, net=NET,
            execution_mode=ExecutionMode.GATED, gated_capacity=n_ai,
            fused_gated=True, expert_dtype="bfloat16",
            audit_nmse_threshold=BF16_AUDIT_NMSE,
        )

        def scan(engine):
            return lambda: engine.run(
                schedule, modes, n_slots=n_slots, n_ues=n_ues,
                ue_keys=ue_keys,
            )[1]

        times, trajs = _timed_set(
            {"conc": scan(conc), "gated": scan(gated),
             "fused": scan(fused), "bf16": scan(bf16)},
            repeats,
        )
        t_conc, t_gated = times["conc"], times["gated"]
        t_fused, t_bf16 = times["fused"], times["bf16"]
        traj_c, traj_g = trajs["conc"], trajs["gated"]
        traj_f, traj_b = trajs["fused"], trajs["bf16"]
        # one executable, one measurement: off-TPU the fused engine runs
        # the ref composition, which is the same XLA program as unfused —
        # an independent re-timing would report scheduler jitter as a
        # (anti-)speedup
        fused_shares_program = jax.default_backend() != "tpu"
        if fused_shares_program:
            t_fused = t_gated

        # contract 1: gated == concurrent, bitwise, on every physical leaf
        eq = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            physical_trajectory(traj_c), physical_trajectory(traj_g),
        )
        if not all(jax.tree.leaves(eq)):
            bad = [k for k, v in eq.items() if not all(jax.tree.leaves(v))]
            raise AssertionError(f"gated != concurrent at share {share}: {bad}")

        # contract 2: fused == unfused on *every* leaf, cost accounting
        # included (same FLOPs executed, no overflow difference)
        eq_f = jax.tree.map(
            lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
            traj_g, traj_f,
        )
        if not all(jax.tree.leaves(eq_f)):
            bad = [k for k, v in eq_f.items() if not all(jax.tree.leaves(v))]
            raise AssertionError(f"fused != unfused at share {share}: {bad}")

        # bf16 is deliberately not bitwise; the audit must stay quiet on
        # these benign channels (a trip here means the guard is miscalibrated)
        bf16_trips = int(np.asarray(traj_b["audit_tripped"]).sum())
        if bf16_trips:
            raise AssertionError(
                f"bf16 audit tripped {bf16_trips} slot-UEs on benign "
                f"channels at share {share}"
            )

        flops_slot = float(
            np.asarray(traj_g["executed_flops"], np.float64).sum(axis=1).mean()
        )
        expected = n_ai * f_ai + n_ues * f_mmse
        if not np.isclose(flops_slot, expected, rtol=1e-6):
            raise AssertionError(
                f"executed FLOPs {flops_slot:.4g} != cost model {expected:.4g}"
            )
        if share == 0.0 and not np.isclose(
            flops_slot, n_ues * f_mmse, rtol=1e-6
        ):
            raise AssertionError("share-0 executed FLOPs != MMSE-only model")
        overflow = int(np.asarray(traj_g["gated_overflow"]).sum())
        if overflow:
            raise AssertionError(
                f"unexpected overflow at provisioned capacity: {overflow}"
            )

        rate_c = n_slots * n_ues / t_conc
        rate_g = n_slots * n_ues / t_gated
        rate_f = n_slots * n_ues / t_fused
        rate_b = n_slots * n_ues / t_bf16
        speedup = t_conc / t_gated
        print(fmt_row(f"{share:.4g} ({n_ai}/{n_ues})",
                      f"{rate_c:.1f} slot-UEs/s",
                      f"{rate_g:.1f} ({speedup:.2f}x)",
                      f"{rate_f:.1f} ({t_gated / t_fused:.2f}x)",
                      f"{rate_b:.1f} slot-UEs/s",
                      f"{flops_slot / 1e9:.3f}",
                      overflow))
        results[f"{share:.4g}"] = {
            "n_ai": n_ai,
            "concurrent_slot_ues_per_s": rate_c,
            "gated_slot_ues_per_s": rate_g,
            "fused_slot_ues_per_s": rate_f,
            "bf16_slot_ues_per_s": rate_b,
            "speedup": speedup,
            "fused_speedup_vs_unfused": t_gated / t_fused,
            "executed_flops_per_slot": flops_slot,
            "provisioned_flops_per_slot": gated.bank.provisioned_flops(n_ues),
            "bitwise_equal": True,
            "fused_bitwise_equal": True,
            "fused_shares_program_with_unfused": fused_shares_program,
            "bf16_audit_tripped": bf16_trips,
        }

    # linearity of the executed-FLOPs accounting in the AI share
    xs = np.asarray([results[k]["n_ai"] for k in results], np.float64)
    ys = np.asarray(
        [results[k]["executed_flops_per_slot"] for k in results], np.float64
    )
    lin = np.allclose(ys, n_ues * f_mmse + xs * f_ai, rtol=1e-6)
    print(fmt_row("executed-FLOPs linear in share", "yes" if lin else "NO"))
    if not lin:
        raise AssertionError("executed-FLOPs accounting is not linear")
    return {
        "n_slots": n_slots,
        "n_ues": n_ues,
        "by_share": results,
        "flops_linear_in_share": lin,
    }


if __name__ == "__main__":
    run()
