"""Streaming churn campaigns: zero-churn equivalence + churn-run rates.

Three legs, all doubling as CI smoke checks:

* **Zero-churn equivalence** — an epoch-chunked streaming run with every
  bank slot attached and no events must be bitwise-equal to the monolithic
  ``ArchesSession.run`` on every trajectory leaf (modes, all KPMs, all
  outputs); raises otherwise.  The warm per-segment wall-time is reported
  next to the monolithic scan's so the segmentation overhead (host
  admission pass + one device dispatch per segment) is visible.
* **Churn scenario** — a campaign over a stable-id universe wider than the
  bank, with attach/detach events across segment boundaries; reports the
  realized resident slot-UEs/s (throughput per *resident* slot-UE, the rate
  a live bank actually serves) and sanity-checks the sentinel/cost
  accounting (detached slot-UEs carry mode ``-1`` and zero executed
  FLOPs); raises otherwise.
* **Pipelined executor + delta checkpoints** — the churn campaign run
  with per-segment durable checkpoints, serial (``pipeline=False``, the
  bitwise reference) vs pipelined (device scan of segment k+1 dispatched
  while a host worker assembles/checkpoints segment k).  Reports the
  checkpointed resident slot-UEs/s both ways, the per-segment wall-time
  breakdown (dispatch / device wait / host assembly / checkpoint write)
  from the executor's ``stats`` hook, and the per-segment delta-checkpoint
  bytes measured at two campaign lengths — raises unless the per-segment
  bytes are independent of campaign length (the O(segment) contract).
"""

from __future__ import annotations

import argparse
import shutil
import tempfile
import time

import numpy as np


def _specs(n_slots: int, n_ues: int, segment_slots: int):
    from repro.core.session import CampaignSpec
    from repro.core.streaming import ChurnSchedule

    base = dict(
        path="batched", scenario="churn_cell", n_ues=n_ues,
        n_slots=n_slots, modes=1,
    )
    zero_churn = CampaignSpec(
        **base,
        churn=ChurnSchedule(
            n_ue_ids=n_ues,
            segment_slots=segment_slots,
            initial=tuple(range(n_ues)),
        ),
    )
    # churn leg: id universe 2x the bank; half resident at t=0, then one
    # detach + one attach per boundary (staggered so residency stays legal)
    n_ids = 2 * n_ues
    events = []
    for i, t0 in enumerate(range(segment_slots, n_slots, segment_slots)):
        events.append((t0, i % n_ues, "detach"))
        events.append((t0, n_ues + (i % n_ues), "attach"))
        if i >= 1:
            events.append((t0, n_ues + ((i - 1) % n_ues), "detach"))
            events.append((t0, (i - 1) % n_ues, "attach"))
    churn = CampaignSpec(
        **base,
        churn=ChurnSchedule(
            n_ue_ids=n_ids,
            segment_slots=segment_slots,
            initial=tuple(range(n_ues)),
            events=tuple(events),
        ),
    )
    return CampaignSpec(**base), zero_churn, churn


def _time_warm(run, repeats: int = 3) -> float:
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run()
    return (time.perf_counter() - t0) / repeats


def run(n_slots: int = 24, n_ues: int = 4, segment_slots: int = 8) -> dict:
    from repro.core.session import ArchesSession

    mono_spec, zc_spec, churn_spec = _specs(n_slots, n_ues, segment_slots)
    mono_sess = ArchesSession(mono_spec)
    zc_sess = ArchesSession(zc_spec, ai_params=mono_sess.ai_params)
    churn_sess = ArchesSession(churn_spec, ai_params=mono_sess.ai_params)

    # -- zero-churn equivalence: streaming == monolithic, bitwise -----------
    mono = mono_sess.run()
    zc = zc_sess.run()
    assert np.array_equal(zc.modes, mono.modes), "zero-churn modes differ"
    for k in mono.kpms:
        assert np.array_equal(zc.kpms[k], mono.kpms[k]), (
            f"zero-churn != monolithic on kpm {k!r}"
        )
    for k in mono.outputs:
        assert np.array_equal(zc.outputs[k], mono.outputs[k]), (
            f"zero-churn != monolithic on output {k!r}"
        )
    mono_warm = _time_warm(mono_sess.run)
    zc_warm = _time_warm(zc_sess.run)
    mono_rate = n_slots * n_ues / mono_warm
    zc_rate = n_slots * n_ues / zc_warm
    n_segments = n_slots // segment_slots
    print(f"zero-churn:  bitwise == monolithic on every leaf "
          f"({n_slots}x{n_ues}, {n_segments} segments)")
    print(f"monolithic:  {mono_rate:8.1f} slot-UEs/s warm")
    print(f"streaming:   {zc_rate:8.1f} slot-UEs/s warm "
          f"({mono_warm / zc_warm:.2f}x of monolithic; overhead is the "
          "host admission pass + per-segment dispatch)")

    # -- churn scenario: resident-rate + sentinel/cost accounting -----------
    hist = churn_sess.run()
    att = np.asarray(hist.attached, bool)
    assert (hist.modes[~att] == -1).all(), "detached mode sentinel broken"
    assert (hist.bank_slot[~att] == -1).all(), "detached bank_slot broken"
    assert (
        np.asarray(hist.outputs["executed_flops"])[~att] == 0
    ).all(), "detached slot-UEs charged executed FLOPs"
    resident_slot_ues = int(att.sum())
    churn_warm = _time_warm(churn_sess.run)
    churn_rate = resident_slot_ues / churn_warm
    print(f"churn:       {churn_rate:8.1f} resident slot-UEs/s warm "
          f"({resident_slot_ues}/{n_slots * hist.n_ues} slot-UEs resident, "
          f"{hist.n_ues}-id universe on a {n_ues}-slot bank)")

    # -- pipelined executor + delta checkpoints ------------------------------
    def _ckpt_run(sess, *, pipeline: bool) -> dict:
        stats: dict = {}
        d = tempfile.mkdtemp(prefix="arches-bench-ck-")
        try:
            sess.run_streaming(checkpoint_dir=d, pipeline=pipeline,
                               stats=stats)
        finally:
            shutil.rmtree(d, ignore_errors=True)
        return stats

    def _time_ckpt(sess, *, pipeline: bool, repeats: int = 3):
        _ckpt_run(sess, pipeline=pipeline)  # warm
        t0 = time.perf_counter()
        stats: dict = {}
        for _ in range(repeats):
            stats = _ckpt_run(sess, pipeline=pipeline)
        return (time.perf_counter() - t0) / repeats, stats

    serial_warm, _ = _time_ckpt(churn_sess, pipeline=False)
    pipe_warm, pipe_stats = _time_ckpt(churn_sess, pipeline=True)
    serial_ck_rate = resident_slot_ues / serial_warm
    pipe_ck_rate = resident_slot_ues / pipe_warm
    segs = max(pipe_stats["segments"], 1)
    breakdown = {
        "dispatch": pipe_stats["dispatch_s"] / segs,
        "wait": pipe_stats["wait_s"] / segs,
        "assembly": pipe_stats["assembly_s"] / segs,
        "checkpoint": pipe_stats["checkpoint_s"] / segs,
    }
    print(f"checkpointed serial:    {serial_ck_rate:8.1f} resident "
          "slot-UEs/s warm (assembly+checkpoint on the dispatch thread)")
    print(f"checkpointed pipelined: {pipe_ck_rate:8.1f} resident "
          f"slot-UEs/s warm ({pipe_ck_rate / serial_ck_rate:.2f}x; device "
          "scan of segment k+1 overlaps host assembly of segment k)")
    print("per-segment wall (pipelined): "
          + "  ".join(f"{k} {v * 1e3:.2f}ms" for k, v in breakdown.items()))

    # O(segment) checkpoint contract: per-segment delta bytes must not
    # grow with campaign length (the monolithic format re-writes the whole
    # horizon every boundary; the delta writes only the segment's rows)
    zc2_spec = _specs(2 * n_slots, n_ues, segment_slots)[1]
    zc2_sess = ArchesSession(
        zc2_spec, ai_params=mono_sess.ai_params, engine=zc_sess.engine
    )
    bytes_1 = _ckpt_run(zc_sess, pipeline=True)["checkpoint_bytes"]
    bytes_2 = _ckpt_run(zc2_sess, pipeline=True)["checkpoint_bytes"]
    all_bytes = bytes_1 + bytes_2
    assert max(all_bytes) <= 1.05 * min(all_bytes), (
        f"per-segment delta-checkpoint bytes vary with campaign length: "
        f"{bytes_1} at {n_slots} slots vs {bytes_2} at {2 * n_slots}"
    )
    delta_bytes = int(np.mean(all_bytes))
    print(f"delta checkpoints: {delta_bytes} B/segment at {n_slots} and "
          f"{2 * n_slots} slots (length-independent)")

    return {
        "zero_churn_equal": "bitwise",
        "streaming_slot_ues_per_s": zc_rate,
        "monolithic_slot_ues_per_s": mono_rate,
        "churn_resident_slot_ues_per_s": churn_rate,
        "resident_slot_ues": resident_slot_ues,
        "n_segments": n_segments,
        "serial_checkpointed_slot_ues_per_s": serial_ck_rate,
        "pipelined_checkpointed_slot_ues_per_s": pipe_ck_rate,
        "pipeline_speedup": pipe_ck_rate / serial_ck_rate,
        "segment_breakdown_s": breakdown,
        "delta_ckpt_bytes_per_segment": delta_bytes,
        "delta_bytes_length_invariant": "yes",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-slots", type=int, default=24)
    ap.add_argument("--n-ues", type=int, default=4)
    ap.add_argument("--segment-slots", type=int, default=8)
    args = ap.parse_args()
    run(args.n_slots, args.n_ues, args.segment_slots)


if __name__ == "__main__":
    main()
