"""Paper Fig. 4 + Fig. 5: the 3-stage policy-design methodology.

Stage 1: AWGN perturbation sweep (rho in [0,2], paper Eq. 3) through the
MMSE-only pipeline (Fig. 3 harness) recording downstream KPMs.
Stage 2: monotonicity filtering (Spearman |r| >= 0.8).
Stage 3: Pearson + hierarchical clustering redundancy reduction at 0.8.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import NET, SLOT_CFG, fmt_row, get_ai_params, get_pipeline
from repro.core.methodology import (
    design_policy_inputs,
    monotonicity_filter,
    sensitivity_sweep,
    sensitivity_sweep_batched,
)
from repro.phy.pipeline import BatchedPuschPipeline, LinkState
from repro.phy.scenario import GOOD

AERIAL_KPMS = ("code_rate", "sinr", "qam_order", "mcs_index", "tb_size",
               "n_code_blocks", "pdu_length", "ndi", "rsrp")
OAI_KPMS = ("snr", "mac_throughput", "lcid4_throughput", "mac_rx_bytes",
            "lcid4_rx_bytes")


def run(n_trials: int = 4, rho_step: float = 0.2) -> dict:
    pipe = get_pipeline()
    rhos = tuple(np.round(np.arange(0.0, 2.0 + 1e-9, rho_step), 3))

    state = {"link": LinkState(), "i": 0}

    def eval_fn(rho, key):
        state["i"] += 1
        link, out, kpms = pipe.run_slot(
            jax.random.fold_in(key, state["i"]), 1, state["link"], GOOD,
            perturb_rho=rho,
        )
        state["link"] = link
        return {**kpms["aerial"], **kpms["oai"]}

    # Stage 1 — Fig. 4
    t0 = time.perf_counter()
    sweep = sensitivity_sweep(eval_fn, rhos=rhos, n_trials=n_trials)
    t_host = time.perf_counter() - t0
    print("\n== Stage 1: KPM degradation vs rho (paper Fig. 4) ==")
    print(fmt_row("kpm", "rho=0", "rho=1", "rho=2", "trend"))
    for k, name in enumerate(sweep.kpm_names):
        m = sweep.means[:, k]
        trend = "down" if m[-1] < m[0] else ("up" if m[-1] > m[0] else "flat")
        print(fmt_row(name, f"{m[0]:.4g}", f"{m[len(m)//2]:.4g}",
                      f"{m[-1]:.4g}", trend))

    # Stage 2 — monotonicity
    kept = monotonicity_filter(sweep, min_abs_spearman=0.8)
    print("\n== Stage 2: monotonicity filter (|Spearman| >= 0.8) ==")
    for name, r in sorted(kept.items(), key=lambda kv: kv[1]):
        print(fmt_row(name, f"spearman={r:+.3f}"))
    dropped = [n for n in sweep.kpm_names if n not in kept]
    print(fmt_row("dropped", ", ".join(dropped) if dropped else "(none)", w=60))

    # Stage 3 — Fig. 5 (clustering on raw per-slot samples across the sweep)
    flat = {  # (R*T,) per KPM
        name: sweep.samples[:, :, k].reshape(-1)
        for k, name in enumerate(sweep.kpm_names)
    }
    aerial = {n: flat[n] for n in AERIAL_KPMS if n in flat}
    oai = {n: flat[n] for n in OAI_KPMS if n in flat}
    selected, a_res, o_res = design_policy_inputs(aerial, oai)

    print("\n== Stage 3: redundancy reduction (threshold 0.8, paper Fig. 5) ==")
    print("Aerial clusters:")
    for c in sorted(set(a_res.labels)):
        members = [a_res.names[i] for i in range(len(a_res.names))
                   if a_res.labels[i] == c]
        print(fmt_row(f"  cluster {c}", ", ".join(members), w=70))
    print("OAI clusters:")
    for c in sorted(set(o_res.labels)):
        members = [o_res.names[i] for i in range(len(o_res.names))
                   if o_res.labels[i] == c]
        print(fmt_row(f"  cluster {c}", ", ".join(members), w=70))
    print("\nSelected policy inputs:", ", ".join(selected))

    # link-adaptation block check (paper: code_rate..n_code_blocks cluster)
    la = ["mcs_index", "tb_size", "qam_order", "code_rate"]
    la_pairs = []
    for i, a in enumerate(la):
        for b in la[i + 1:]:
            ia, ib = a_res.names.index(a), a_res.names.index(b)
            la_pairs.append(abs(a_res.corr[ia, ib]))
    print(f"link-adaptation block |corr| range: "
          f"{min(la_pairs):.2f}..{max(la_pairs):.2f} (paper: 0.81..1.00)")

    # Stage 1 on the batched engine: the rho grid rides the UE axis of one
    # scan-compiled campaign instead of O(R*T) host dispatches.
    params, _ = get_ai_params()
    engine = BatchedPuschPipeline(SLOT_CFG, params, net=NET)
    sensitivity_sweep_batched(  # warm: compile the perturbed scan
        engine, lambda s: GOOD, rhos=rhos, n_trials=n_trials
    )
    t0 = time.perf_counter()
    sweep_b = sensitivity_sweep_batched(
        engine, lambda s: GOOD, rhos=rhos, n_trials=n_trials
    )
    t_batched = time.perf_counter() - t0
    kept_b = monotonicity_filter(sweep_b, min_abs_spearman=0.8)
    common = set(kept) & set(kept_b)
    print("\n== Stage 1 on the batched engine (scan-compiled rho grid) ==")
    print(fmt_row("host loop", f"{t_host:.1f} s",
                  f"{len(rhos) * n_trials} pipeline dispatches"))
    print(fmt_row("batched scan (warm)", f"{t_batched:.1f} s",
                  f"one campaign, {len(rhos) * n_trials} UEs"))
    print(fmt_row("speedup", f"{t_host / t_batched:.1f}x"))
    print(fmt_row("monotone-KPM agreement",
                  f"{len(common)}/{len(set(kept) | set(kept_b))}",
                  "(host vs batched stage-2 survivors)"))

    return {
        "t_stage1_host_s": t_host,
        "t_stage1_batched_s": t_batched,
        "stage1_speedup": t_host / t_batched,
        "monotone_kpms_batched": kept_b,
        "monotone_kpms": kept,
        "selected": selected,
        "la_corr_min": min(la_pairs),
        "n_aerial_clusters": len(set(a_res.labels)),
        "n_oai_clusters": len(set(o_res.labels)),
    }


if __name__ == "__main__":
    run()
