"""Resident campaign service: API-driven campaigns + drain/resume (PR 9).

Two legs, both doubling as CI smoke checks:

* **Zero-churn over the northbound API** — a churn-free campaign is
  submitted as JSON over the live HTTP API, polled through its status
  transitions (``queued -> running -> completed``), and the completed
  history must be **bitwise-equal** to the monolithic
  ``ArchesSession.run()`` on every leaf (the ``as_streaming_spec`` lift
  + zero-churn contract carried through the service path); the segment
  telemetry must arrive at the JSONL exporter lossless (drop counter
  exactly zero); raises otherwise.  Reports the end-to-end service wall
  clock (submit -> completed over HTTP, compile included) next to the
  warm direct-call streaming rate, so the dispatch/persist/export
  overhead is a measured number.
* **Kill-and-resume through the service** — a churn campaign is drained
  at its first segment boundary (the deterministic in-process stand-in
  for SIGTERM; the subprocess SIGTERM path is `tests/test_service.py`),
  left ``interrupted`` with a durable checkpoint, then a restarted
  service on the same state dir resumes it to completion: the stitched
  history must be bitwise-equal to the uninterrupted
  ``run_streaming()``; raises otherwise.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
import urllib.request

import numpy as np


def _assert_equal(a, b, what: str) -> None:
    assert np.array_equal(np.asarray(a.modes), np.asarray(b.modes)), (
        f"{what}: modes diverged"
    )
    for k in b.kpms:
        assert np.array_equal(
            np.asarray(a.kpms[k]), np.asarray(b.kpms[k])
        ), f"{what}: kpm {k!r} diverged"
    for k in b.outputs:
        assert np.array_equal(
            np.asarray(a.outputs[k]), np.asarray(b.outputs[k])
        ), f"{what}: output {k!r} diverged"


def _time_warm(run, repeats: int = 3) -> float:
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run()
    return (time.perf_counter() - t0) / repeats


def run(n_slots: int = 24, n_ues: int = 4, segment_slots: int = 4) -> dict:
    from repro.core.session import ArchesSession, CampaignSpec, spec_hash
    from repro.core.streaming import ChurnSchedule
    from repro.service import CampaignService, JsonlExporter
    from repro.service.api import ServiceAPI

    modes = tuple(
        tuple((s + u) % 2 for u in range(n_ues)) for s in range(n_slots)
    )
    spec = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=n_ues,
        n_slots=n_slots, n_prb=6, seed=3, modes=modes,
    )
    mono = ArchesSession(spec)
    hist_m = mono.run()
    n_segments = n_slots // segment_slots

    # -- zero-churn campaign over the live HTTP API -------------------------
    with tempfile.TemporaryDirectory() as state:
        jsonl = os.path.join(state, "telemetry.jsonl")
        svc = CampaignService(
            state, max_segment_slots=segment_slots,
            exporters=[JsonlExporter(jsonl)], ai_params=mono.ai_params,
        ).start()
        api = ServiceAPI(svc).start()
        t0 = time.perf_counter()
        req = urllib.request.Request(
            api.url + "/campaigns", data=spec.to_json().encode(),
            headers={"Content-Type": "application/json"}, method="POST",
        )
        with urllib.request.urlopen(req, timeout=10) as r:
            cid = json.loads(r.read().decode())["campaign_id"]
        transitions: list[str] = []
        while True:
            with urllib.request.urlopen(
                api.url + f"/campaigns/{cid}", timeout=10
            ) as r:
                st = json.loads(r.read().decode())
            if not transitions or transitions[-1] != st["state"]:
                transitions.append(st["state"])
            if st["state"] in ("completed", "failed", "cancelled"):
                break
            time.sleep(0.02)
        service_wall = time.perf_counter() - t0
        assert st["state"] == "completed", (
            f"service campaign ended {st['state']!r}: {st['error']}"
        )
        assert st["segments_done"] == st["n_segments"] == n_segments
        assert st["spec_hash"] == spec_hash(spec), "provenance hash diverged"
        assert st["checkpoint_steps"], "no checkpoint lineage reported"
        _assert_equal(svc.result(cid), hist_m, "service zero-churn")
        api.stop()
        assert svc.drain(timeout=60), "drain timed out"
        with open(jsonl) as f:
            rows = [json.loads(line) for line in f]
        assert [r["seg_idx"] for r in rows] == list(range(n_segments)), (
            "telemetry export lost segments"
        )
        exported = svc.pump.counters()
        assert exported["dropped"] == 0, "telemetry drops in a tiny campaign"

    print(f"service API:  zero-churn campaign bitwise == monolithic run "
          f"({n_slots}x{n_ues}, {n_segments} segments, "
          f"transitions {'->'.join(transitions)})")
    print(f"telemetry:    {exported['exported']} segment samples exported "
          f"lossless ({exported['dropped']} dropped)")

    # -- kill-and-resume through the service path ---------------------------
    churn_spec = CampaignSpec(
        path="batched", scenario="churn_cell", n_ues=n_ues,
        n_slots=n_slots, n_prb=6, seed=3,
        modes=tuple(tuple((s + u) % 2 for u in range(n_ues + 1))
                    for s in range(n_slots)),
        churn=ChurnSchedule(
            n_ue_ids=n_ues + 1, segment_slots=segment_slots,
            initial=tuple(range(n_ues - 1)),
            events=((segment_slots, n_ues, "attach"),
                    (segment_slots + 1, 0, "detach")),
        ),
    )
    sess = ArchesSession(churn_spec, ai_params=mono.ai_params)
    ref = sess.run_streaming()
    with tempfile.TemporaryDirectory() as state:
        def drain_at_first_boundary(service, rec, ev):
            if ev.seg_idx == 0:
                service.request_drain()

        svc = CampaignService(
            state, max_segment_slots=segment_slots,
            ai_params=mono.ai_params,
            segment_callback=drain_at_first_boundary,
        ).start()
        cid = svc.submit(churn_spec)
        deadline = time.monotonic() + 120
        while not svc.draining and time.monotonic() < deadline:
            time.sleep(0.02)
        assert svc.drain(timeout=120), "drain timed out"
        st = svc.status(cid)
        assert st["state"] == "interrupted", f"expected interrupt, {st}"
        assert st["checkpoint_steps"], "interrupted without a checkpoint"

        svc2 = CampaignService(
            state, max_segment_slots=segment_slots, ai_params=mono.ai_params,
        ).start()
        assert svc2.wait(cid, timeout=180) == "completed"
        _assert_equal(svc2.result(cid), ref, "drain+resume")
        np.testing.assert_array_equal(svc2.result(cid).attached, ref.attached)
        assert svc2.drain(timeout=60)

    direct_warm = _time_warm(sess.run_streaming)
    direct_rate = n_slots * n_ues / direct_warm
    cold_rate = n_slots * n_ues / service_wall
    print(f"kill+resume:  drained at segment 1/{n_segments}, restarted "
          "service resumed bitwise == uninterrupted on every leaf")
    print(f"direct call:  {direct_rate:8.1f} slot-UEs/s warm (no service)")
    print(f"service path: {service_wall*1e3:8.1f} ms submit->completed over "
          "HTTP (cold: compile + checkpoints + dispatch/persist/export)")
    return {
        "zero_churn_service_equal": "bitwise",
        "drain_resume_equal": "bitwise",
        "status_transitions": transitions,
        "n_segments": n_segments,
        "telemetry_exported": exported["exported"],
        "telemetry_dropped": exported["dropped"],
        "service_campaign_wall_s": service_wall,
        # cold end-to-end rate: deliberately NOT a *slot_ues_per_s key, so
        # the >20% regression gate skips it (compile-dominated and noisy)
        "slot_ues_per_s_cold": cold_rate,
        "direct_streaming_slot_ues_per_s": direct_rate,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-slots", type=int, default=24)
    ap.add_argument("--n-ues", type=int, default=4)
    ap.add_argument("--segment-slots", type=int, default=4)
    args = ap.parse_args()
    run(args.n_slots, args.n_ues, args.segment_slots)


if __name__ == "__main__":
    main()
