"""§Roofline: three-term roofline analysis from the compiled dry-run.

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

Hardware target: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() on the SPMD-partitioned module reports per-device numbers;
collective bytes are parsed from the partitioned HLO (launch/dryrun.py).

MODEL_FLOPS uses 6·N·D for training cells (fwd+bwd) and 2·N_active·D for
inference cells (fwd only, D = tokens processed per step); the ratio to HLO
FLOPs exposes remat/redundancy waste.
"""

from __future__ import annotations

import json
import os
import sys

from repro.models.config import ALL_SHAPES, get_config

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

CELLS = {c.name: c for c in ALL_SHAPES}


def model_flops(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    cell = CELLS[shape]
    n = cfg.n_active_params() if cfg.moe else cfg.n_params()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    tokens = cell.global_batch  # one new token per sequence
    return 2.0 * n * tokens


def roofline_row(rec: dict) -> dict:
    chips = rec["n_chips"]
    t_compute = rec["flops_per_device"] / PEAK_FLOPS
    t_memory = rec["bytes_accessed_per_device"] / HBM_BW
    t_coll = rec["collective_bytes_total"] / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = rec["flops_per_device"] * chips
    useful = mf / hlo_total if hlo_total > 0 else float("nan")
    bound = max(terms.values())
    # roofline fraction: useful-compute time over the bound term
    t_useful = (mf / chips) / PEAK_FLOPS
    frac = t_useful / bound if bound > 0 else float("nan")
    return {
        **rec,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "peak_hbm_gb": rec["peak_hbm_per_device"] / 2**30,
    }


def _expert_param_count(net, *_unused) -> float:
    """Analytic parameter count of the residual-CNN expert (per antenna)."""
    c = net.channels
    kh, kw = net.kernel_hw
    return float(
        (c * 2 * kh * kw + c)                      # stem
        + net.n_res_blocks * 2 * (c * c * kh * kw + c)  # body
        + (2 * c) * c * kh * kw + 2 * c            # up-projection
        + 2 * (2 * c) * kh * kw + 2                # head
    )


def gated_hot_path(
    n_ues: int = 16,
    shares: tuple[float, ...] = (1.0 / 16.0, 0.25, 1.0),
) -> list[dict]:
    """Analytic roofline for the gated expert hot path (per scan step).

    Compares the HBM traffic of the *unfused* triple (gather-compact ->
    folded-GEMM -> scatter: the capacity-K sub-batch is materialized twice
    and every UE's tile crosses HBM again in the scatter) against the
    *fused* kernel (DMA-steered gather feeds the GEMM directly, scatter is
    the aliased output write — K input tiles in, K output blocks out, the
    folded weights resident in VMEM across the capacity grid).  A bf16
    column halves the GEMM operand bytes (outputs stay f32).  FLOPs are
    identical across all three — fusion is purely a memory/launch win, so
    the interesting number is arithmetic intensity vs the v5e ridge point.
    """
    from benchmarks.common import NET, SLOT_CFG

    cfg, net = SLOT_CFG, NET
    in_tile = 2 * cfg.n_dmrs_sym * cfg.n_ant * cfg.n_pilot_sc * 4  # f32 bytes
    out_tile = 2 * cfg.n_dmrs_sym * cfg.n_ant * cfg.n_sc * 4
    w_bytes = _expert_param_count(net) * 4
    f_ai = net.flops(cfg)
    ridge = PEAK_FLOPS / HBM_BW
    print(f"\n== Gated hot path (analytic, per scan step, U={n_ues}) ==")
    print(f"   tiles: in {in_tile} B, out {out_tile} B, weights "
          f"{w_bytes / 1e3:.1f} kB; expert {f_ai / 1e6:.1f} MFLOP/UE; "
          f"v5e ridge {ridge:.0f} FLOP/B")
    hdr = ("| AI share | K | unfused MB | fused MB | fused bf16 MB | "
           "traffic cut | intensity F/B | bound |")
    print(hdr)
    print("|" + "---|" * 8)
    rows = []
    for share in shares:
        k = max(int(round(share * n_ues)), 1)
        # unfused: gather (rd K in, wr K in) + GEMM (rd K in + W, wr K out)
        # + scatter (rd K out + U base, wr U out)
        unfused = (2 * k * in_tile) + (k * in_tile + w_bytes + k * out_tile) \
            + (k * out_tile + 2 * n_ues * out_tile)
        # fused: rd K in + W once (VMEM-resident), wr K aliased out blocks
        fused = k * in_tile + w_bytes + k * out_tile
        # bf16: GEMM operand bytes halve, f32 accumulate/output unchanged
        fused_bf16 = k * in_tile // 2 + w_bytes / 2 + k * out_tile
        flops = k * f_ai
        intensity = flops / fused
        bound = "compute" if intensity > ridge else "memory"
        print(f"| {share:.4g} | {k} | {unfused / 1e6:.3f} | "
              f"{fused / 1e6:.3f} | {fused_bf16 / 1e6:.3f} | "
              f"{unfused / fused:.1f}x | {intensity:.0f} | {bound} |")
        rows.append({
            "share": share, "capacity": k,
            "unfused_bytes": unfused, "fused_bytes": fused,
            "fused_bf16_bytes": fused_bf16,
            "traffic_cut": unfused / fused,
            "arithmetic_intensity": intensity, "bound": bound,
        })
    print("   (plus 2 launch boundaries/step removed; bf16 also halves the "
          "MXU ridge so the bound column is conservative)")
    return rows


LEVERS = {
    "compute": "cut non-useful FLOPs (remat policy, fused attention, avoid "
               "fp32 upcasts)",
    "memory": "keep activations bf16, shard the fp32 softmax/vocab axis, "
              "larger effective arithmetic intensity per HBM pass",
    "collective": "re-shard to cut all-gathers (2D sharding of embed/vocab), "
                  "overlap collectives with compute, gradient compression",
}


def render(records: list[dict], mesh: str = "single") -> str:
    rows = [roofline_row(r) for r in records
            if r.get("status") == "ok" and r.get("mesh") == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = []
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac | peak HBM GB |")
    out.append(hdr)
    out.append("|" + "---|" * 9)
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['peak_hbm_gb']:.1f} |"
        )
    return "\n".join(out)


def merge_calibrated(records: list[dict], calib_path: str) -> list[dict]:
    """Overlay scan-corrected FLOP/byte/collective terms onto raw records.

    Raw ``memory_analysis`` numbers (peak HBM) stay from the full-scan
    lowering — buffer assignment is correct there; only the cost-model terms
    suffer the while-body-once undercount.
    """
    if not os.path.exists(calib_path):
        return records
    with open(calib_path) as f:
        calib = {(r["arch"], r["shape"], r["mesh"]): r
                 for r in json.load(f) if r.get("status") == "ok"}
    out = []
    for r in records:
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        if r.get("status") == "ok" and key in calib:
            c = calib[key]
            r = {**r,
                 "flops_per_device": c["flops_per_device"],
                 "bytes_accessed_per_device": c["bytes_accessed_per_device"],
                 "collective_bytes_per_device": c["collective_bytes_per_device"],
                 "collective_bytes_total": c["collective_bytes_total"],
                 "calibrated": True}
        out.append(r)
    return out


def run(path: str = "dryrun_results.json",
        calib_path: str = "dryrun_calibrated.json") -> list[dict]:
    gated_hot_path()
    if not os.path.exists(path):
        print(f"[roofline] {path} missing — run python -m repro.launch.dryrun --all")
        return []
    with open(path) as f:
        records = json.load(f)
    records = merge_calibrated(records, calib_path)
    ok = [r for r in records if r.get("status") == "ok"]
    n_cal = sum(1 for r in ok if r.get("calibrated"))
    print(f"[roofline] {n_cal}/{len(ok)} cells carry scan-corrected terms")
    print(f"\n== Roofline (single-pod, {len(ok)} compiled cells) ==")
    print(render(records, mesh="single"))
    rows = [roofline_row(r) for r in ok if r["mesh"] == "single"]
    if rows:
        worst = min(rows, key=lambda r: r["roofline_fraction"])
        coll = max(rows, key=lambda r: r["t_collective_s"])
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_fraction']:.2f})")
        print(f"most collective-bound:   {coll['arch']} x {coll['shape']} "
              f"({coll['t_collective_s']:.3g}s)")
        for kind, lever in LEVERS.items():
            n = sum(1 for r in rows if r["dominant"] == kind)
            print(f"  {kind}-bound cells: {n:2d} — lever: {lever}")
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
