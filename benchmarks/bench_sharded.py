"""Sharded multi-cell engine: 1-device parity + forced-multi-shard scaling.

Two legs, both doubling as CI smoke checks:

* **Parity (in-process)** — the sharded entry on the local (1-device CI)
  mesh must be bitwise-equal on physical trajectory leaves to the plain
  unsharded engine under a trivial topology; raises otherwise.  Warm
  wall-time of the sharded scan is reported next to the unsharded engine's
  so the shard_map wrapper's overhead is visible.
* **Scaling (subprocess)** — re-runs the same campaign under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (default 8) so
  the scan actually executes across N shards, and reports slot-UEs/s plus
  the per-shard UE count.  On the 2-core CI container the forced shards
  oversubscribe the same cores — the number demonstrates the path works
  and what it costs there, not accelerator scaling.

Invoked as a module (``python -m benchmarks.bench_sharded --child ...``)
it runs the scaling leg and prints one JSON line (the parent parses it).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _build(n_ues: int, topo_spec=None):
    from repro.core.topology import CellTopology, TopologySpec
    from repro.phy.ai_estimator import AiEstimatorConfig, init_params
    from repro.phy.nr import SlotConfig
    from repro.phy.pipeline import BatchedPuschPipeline

    cfg = SlotConfig(n_prb=24)
    net = AiEstimatorConfig(channels=8, n_res_blocks=1)
    params = init_params(jax.random.PRNGKey(0), cfg, net)
    engine = BatchedPuschPipeline(cfg, params, net=net)
    topo = CellTopology.build(
        topo_spec or TopologySpec(n_cells=2), n_ues
    )
    return cfg, engine, topo


def _sharded_callable(cfg, engine, topo, n_slots: int, n_ues: int):
    """One cached jitted callable + its args (timing needs a stable fn)."""
    from repro.core.topology import open_loop_fn
    from repro.phy.channel import broadcast_params_to_ues
    from repro.phy.pipeline import init_device_link, resolve_schedule
    from repro.phy.scenario import good_poor_good_schedule

    sched = good_poor_good_schedule(
        poor_start=n_slots // 3, poor_end=2 * n_slots // 3
    )
    profile, params = resolve_schedule(cfg, sched, n_slots, n_ues)
    params = broadcast_params_to_ues(params, n_ues)
    key = jax.random.PRNGKey(3)
    ue_keys = jax.vmap(lambda u: jax.random.fold_in(key, u))(
        jnp.arange(n_ues)
    )
    modes = jnp.ones((n_slots, n_ues), jnp.int32).at[:, 0].set(0)
    args = (
        init_device_link(n_ues), ue_keys, modes, params,
        jnp.asarray(topo.cell_of_ue), topo.cell_params,
    )
    return jax.jit(open_loop_fn(engine, topo, profile)), args, sched, modes


def _time_warm(fn, args, repeats: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def _child(n_slots: int, n_ues: int) -> dict:
    """Scaling leg: runs on whatever device count XLA was forced to."""
    cfg, engine, topo = _build(n_ues)
    fn, args, _, _ = _sharded_callable(cfg, engine, topo, n_slots, n_ues)
    warm_s = _time_warm(fn, args)
    return {
        "devices": len(jax.devices()),
        "n_shards": topo.n_shards,
        "ues_per_shard": topo.ues_per_shard,
        "slot_ues_per_s": n_slots * n_ues / warm_s,
    }


def run(n_slots: int = 16, n_ues: int = 8, forced_shards: int = 8) -> dict:
    cfg, engine, topo = _build(n_ues)
    fn, args, sched, modes = _sharded_callable(
        cfg, engine, topo, n_slots, n_ues
    )

    # -- parity: sharded entry == plain engine, bitwise ---------------------
    _, traj_s = fn(*args)
    _, traj_u = engine.run(
        sched, modes, n_slots=n_slots, n_ues=n_ues, key=jax.random.PRNGKey(3)
    )
    for leaf in ("tb_ok", "mcs", "phy_bits_per_s", "executed_flops"):
        assert np.array_equal(
            np.asarray(traj_s[leaf]), np.asarray(traj_u[leaf])
        ), f"sharded != unsharded on {leaf}"
    assert np.array_equal(
        np.asarray(traj_s["kpms"]["aerial"]["sinr"]),
        np.asarray(traj_u["kpms"]["aerial"]["sinr"]),
    ), "sharded != unsharded on sinr"
    sharded_warm = _time_warm(fn, args)
    t0 = time.perf_counter()
    out = engine.run(
        sched, modes, n_slots=n_slots, n_ues=n_ues, key=jax.random.PRNGKey(3)
    )
    jax.block_until_ready(out)
    unsharded_warm = time.perf_counter() - t0
    rate_1dev = n_slots * n_ues / sharded_warm
    print(f"1-device parity:   bitwise on all physical leaves "
          f"({n_slots}x{n_ues}, {topo.n_shards} shard(s))")
    print(f"1-device sharded:  {rate_1dev:8.1f} slot-UEs/s warm "
          f"(unsharded engine {n_slots * n_ues / unsharded_warm:8.1f})")

    # -- scaling: forced multi-device mesh in a subprocess ------------------
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={forced_shards} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded", "--child",
         "--n-slots", str(n_slots), "--n-ues", str(n_ues)],
        env=env, capture_output=True, text=True, timeout=540,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"forced-{forced_shards}-shard child failed:\n{proc.stderr[-3000:]}"
        )
    forced = json.loads(proc.stdout.strip().splitlines()[-1])
    print(f"forced {forced['n_shards']} shards: "
          f"{forced['slot_ues_per_s']:8.1f} slot-UEs/s warm "
          f"({forced['ues_per_shard']} UEs/shard; CPU cores shared)")
    return {
        "parity": "bitwise",
        "one_device_slot_ues_per_s": rate_1dev,
        "forced": forced,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--n-slots", type=int, default=16)
    ap.add_argument("--n-ues", type=int, default=8)
    ap.add_argument("--forced-shards", type=int, default=8)
    args = ap.parse_args()
    if args.child:
        print(json.dumps(_child(args.n_slots, args.n_ues)))
    else:
        run(args.n_slots, args.n_ues, args.forced_shards)


if __name__ == "__main__":
    main()
