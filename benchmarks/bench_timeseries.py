"""Paper Fig. 9: PHY throughput over time across good -> poor -> good,
under continuous AI, continuous MMSE, and ARCHES switching.

Also benchmarks the batched multi-UE scan engine against the seed host
loop: slots*UEs/s at batch 16, plus the per-UE trajectory-identity check
(a batched run must equal independent single-UE runs with the same keys).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import N_SLOTS, fmt_row, get_pipeline
from repro.core.dapp import DApp, connect_dapp
from repro.core.e3 import E3Agent
from repro.core.policy import DecisionTreePolicy, fit_decision_tree
from repro.core.runtime import ArchesRuntime
from repro.core.telemetry import SELECTED_KPMS
from repro.phy.pipeline import LinkState
from repro.phy.scenario import good_poor_good_schedule


def _static_run(pipe, schedule, mode, n):
    link = LinkState()
    tput = []
    for i in range(n):
        link, out, kpms = pipe.run_slot(
            jax.random.PRNGKey(i), mode, link, schedule(i)
        )
        tput.append(out["phy_bits_per_s"])
    return np.asarray(tput)


def run(n_phase: int | None = None) -> dict:
    n_phase = n_phase or max(N_SLOTS // 3, 10)
    n = 3 * n_phase
    pipe = get_pipeline()
    schedule = good_poor_good_schedule(poor_start=n_phase, poor_end=2 * n_phase)

    # dashed lines: continuous execution of each expert
    tput_ai = _static_run(pipe, schedule, 0, n)
    tput_mmse = _static_run(pipe, schedule, 1, n)

    # train the switching policy on profiled data from both experts
    X, y = [], []
    for mode in (0, 1):
        link = LinkState()
        for i in range(n):
            link, out, kpms = pipe.run_slot(
                jax.random.PRNGKey(10_000 + i), mode, link, schedule(i)
            )
            flat = {**kpms["aerial"], **kpms["oai"]}
            X.append([flat[k] for k in SELECTED_KPMS])
            y.append(0 if schedule(i).interference else 1)
    tree = fit_decision_tree(np.asarray(X, np.float32), np.asarray(y), depth=2)
    policy = DecisionTreePolicy(tree, SELECTED_KPMS)

    # solid line: ARCHES
    agent = E3Agent()
    dapp = DApp(policy, SELECTED_KPMS, window_slots=2)
    connect_dapp(agent, dapp)
    runtime = ArchesRuntime(
        pipe.make_slot_fn(schedule), agent, default_mode=1, fail_safe_mode=1,
        ttl_slots=8, keep_outputs=True,
    )
    hist = runtime.run(range(n))
    tput_arches = np.asarray([r.output["phy_bits_per_s"] for r in hist.records])
    modes = hist.modes

    def phase(x, lo, hi):
        return float(np.mean(x[lo:hi])) / 1e6

    g1, p, g2 = (2, n_phase), (n_phase + 2, 2 * n_phase), (2 * n_phase + 2, n)
    print("\n== PHY throughput time series (paper Fig. 9) ==")
    print(fmt_row("phase", "AI (Mbps)", "MMSE (Mbps)", "ARCHES (Mbps)",
                  "ARCHES mode"))
    for name, (lo, hi) in (("good#1", g1), ("poor", p), ("good#2", g2)):
        frac_ai = float(np.mean(modes[lo:hi] == 0))
        print(fmt_row(name, f"{phase(tput_ai, lo, hi):.1f}",
                      f"{phase(tput_mmse, lo, hi):.1f}",
                      f"{phase(tput_arches, lo, hi):.1f}",
                      f"{frac_ai*100:.0f}% AI"))
    n_sw = int(hist.final_state.n_switches)
    print(fmt_row("mode switches", n_sw, "(transitions at slot boundaries)"))

    # ARCHES must track the better expert in each phase
    ok = (
        np.mean(modes[slice(*p)] == 0) > 0.5
        and np.mean(modes[slice(*g1)] == 1) > 0.5
    )
    print(fmt_row("tracks conditions", "yes" if ok else "NO"))
    return {
        "tput_ai_poor": phase(tput_ai, *p),
        "tput_mmse_poor": phase(tput_mmse, *p),
        "tput_arches_poor": phase(tput_arches, *p),
        "n_switches": n_sw,
        "tracks": bool(ok),
    }


def run_batched(
    n_slots: int = 100,
    n_ues: int = 16,
    *,
    host_probe_slots: int = 40,
    check_identity: bool = True,
) -> dict:
    """Batched scan engine vs seed host loop: slots*UEs/s at batch 16.

    The host-loop baseline is the single-UE ``PuschPipeline`` driven one
    ``run_slot`` at a time (the seed architecture); its per-slot rate scales
    linearly in UEs (each UE is an independent host iteration).  The probe
    sequence is executed once untimed first so OLLA-driven MCS changes have
    populated the per-``(qm, tbs)`` jit cache — the timed pass measures
    steady-state loop throughput, not compilation.  The batched engine runs
    the full ``n_slots x n_ues`` campaign as one compiled ``lax.scan``.
    """
    from benchmarks.common import NET, SLOT_CFG, get_ai_params
    from repro.phy.pipeline import BatchedPuschPipeline

    params, _ = get_ai_params()
    pipe = get_pipeline()
    engine = BatchedPuschPipeline(SLOT_CFG, params, net=NET)
    schedule = good_poor_good_schedule(
        poor_start=n_slots // 3, poor_end=2 * n_slots // 3
    )

    # -- seed host loop rate (per slot-UE), steady state --------------------
    def host_probe():
        link = LinkState()
        for i in range(host_probe_slots):
            link, out, _ = pipe.run_slot(
                jax.random.PRNGKey(i), 1, link, schedule(i)
            )
        return out

    host_probe()  # warm every (qm, tbs) trace this sequence hits
    t0 = time.perf_counter()
    host_probe()
    host_rate = host_probe_slots / (time.perf_counter() - t0)  # slot-UEs/s

    # -- batched scan engine ------------------------------------------------
    ue_keys = jax.random.split(jax.random.PRNGKey(123), n_ues)
    _, traj = engine.run(  # warm compile
        schedule, 1, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
    )
    jax.block_until_ready(traj["tb_ok"])
    t0 = time.perf_counter()
    _, traj = engine.run(
        schedule, 1, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
    )
    jax.block_until_ready(traj["tb_ok"])
    batched_rate = n_slots * n_ues / (time.perf_counter() - t0)
    speedup = batched_rate / host_rate

    print("\n== Batched multi-UE slot engine ==")
    print(fmt_row("config", f"{n_ues} UEs x {n_slots} slots"))
    print(fmt_row("seed host loop (warm)", f"{host_rate:.1f} slot-UEs/s"))
    print(fmt_row("scan engine", f"{batched_rate:.1f} slot-UEs/s"))
    print(fmt_row("speedup", f"{speedup:.1f}x",
                  "(vs steady-state baseline)"))
    if speedup < 5.0:
        print(fmt_row("", "note: both paths are AI-expert",
                      "compute-bound on few-core CPUs;"))
        print(fmt_row("", "dispatch-bound hosts and",
                      "accelerators see larger gains"))

    identical = None
    if check_identity:
        tb, mcs = np.asarray(traj["tb_ok"]), np.asarray(traj["mcs"])
        identical = True
        for ue in range(n_ues):
            _, solo = engine.run(
                schedule, 1, n_slots=n_slots, n_ues=1,
                ue_keys=ue_keys[ue : ue + 1],
            )
            identical = identical and np.array_equal(
                tb[:, ue], np.asarray(solo["tb_ok"])[:, 0]
            ) and np.array_equal(mcs[:, ue], np.asarray(solo["mcs"])[:, 0])
        print(fmt_row("per-UE trajectories == solo runs",
                      "yes" if identical else "NO"))

    return {
        "host_rate": host_rate,
        "batched_rate": batched_rate,
        "speedup": speedup,
        "identical_to_solo": identical,
    }


if __name__ == "__main__":
    run()
    run_batched()
