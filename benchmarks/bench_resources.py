"""Paper Fig. 11: GPU power / utilization / CPU memory per expert.

This container has no power rails, so the proxy model maps each expert's
static compute profile (FLOPs + HBM bytes per slot, from the bank's cost
model) onto the paper's measured GH200 envelope:

    util  = busy_time / slot_time,  busy_time = max(flops/peak, bytes/bw)
    power = idle_power + (max_power - idle_power) * util

calibrated so that unconditional-AI execution under good conditions
reproduces the paper's 164.2 W / 67% and MMSE its 148.4 W / 50%.  What the
proxy then *predicts* — the power gap between experts per condition, and the
saving ARCHES realizes by defaulting to MMSE — is the deliverable, mirroring
the paper's performance-per-watt argument.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import NET, SLOT_CFG, campaign, fmt_row, get_pipeline, median
from repro.core.expert_bank import ExecutionMode
from repro.phy.estimators import estimator_flops

# paper Fig. 11 anchors (GH200, good conditions)
PAPER = {
    "ai_power_w": 164.2, "mmse_power_w": 148.4,
    "ai_util": 0.67, "mmse_util": 0.50,
    "poor_ai_util": 0.36, "poor_mmse_util": 0.35,
}


def _calibrate():
    """Solve the 2-point linear model from the paper's good-condition data."""
    f_ai = NET.flops(SLOT_CFG) + estimator_flops(SLOT_CFG)  # concurrent: both
    f_mmse = estimator_flops(SLOT_CFG)
    # busy-time proxy: FLOPs dominate for the CNN; normalize by slot budget
    u_ai, u_mmse = PAPER["ai_util"], PAPER["mmse_util"]
    # util = base_util + k * flops  (base = PHY pipeline minus estimator)
    k = (u_ai - u_mmse) / (f_ai - f_mmse)
    base_util = u_mmse - k * f_mmse
    # power = idle + c * util
    c = (PAPER["ai_power_w"] - PAPER["mmse_power_w"]) / (u_ai - u_mmse)
    idle = PAPER["ai_power_w"] - c * u_ai
    return k, base_util, c, idle


def _load_scale(cond: str) -> float:
    """Scheduling-grant duty factor: poor conditions lower the GPU load
    (paper: 'reduced scheduling grants lower overall GPU load')."""
    tput_good = median(campaign(1, "good")["phy_throughput"])
    tput = median(campaign(1, cond)["phy_throughput"])
    return 0.35 + 0.65 * (tput / tput_good)


def run() -> dict:
    k, base_util, c, idle = _calibrate()
    f_mmse = estimator_flops(SLOT_CFG)
    f_ai_only = NET.flops(SLOT_CFG)

    def model(flops, cond):
        util = (base_util + k * flops) * _load_scale(cond)
        return util, idle + c * util

    print("\n== GPU power/utilization proxy (paper Fig. 11) ==")
    print(fmt_row("condition", "expert", "util (ours)", "power W (ours)",
                  "paper util/W"))
    rows = {}
    for cond in ("good", "poor"):
        for name, fl in (("AI", f_ai_only + f_mmse), ("MMSE", f_mmse)):
            u, p = model(fl, cond)
            paper_ref = {
                ("good", "AI"): "67% / 164.2", ("good", "MMSE"): "50% / 148.4",
                ("poor", "AI"): "36% / ~149", ("poor", "MMSE"): "35% / ~148",
            }[(cond, name)]
            print(fmt_row(cond, name, f"{u*100:.0f}%", f"{p:.1f}", paper_ref))
            rows[(cond, name)] = (u, p)

    d_good = rows[("good", "AI")][1] - rows[("good", "MMSE")][1]
    d_poor = rows[("poor", "AI")][1] - rows[("poor", "MMSE")][1]
    du_good = (rows[("good", "AI")][0] - rows[("good", "MMSE")][0]) * 100
    print("\nDefaulting to MMSE in good conditions saves "
          f"{d_good:.1f} W and {du_good:.0f} pp utilization "
          "(paper: 15.8 W, 17 pp)")
    print(f"Power gap shrinks to {d_poor:.1f} W under poor conditions "
          "(paper: ~1 W)")

    # selected-only vs concurrent mode energy (beyond-paper quantification)
    pipe_c = get_pipeline()
    pipe_s = get_pipeline(execution_mode=ExecutionMode.SELECTED_ONLY)
    f_conc = pipe_c.bank.flops_for()
    f_sel_mmse = pipe_s.bank.flops_for(1)
    print("\nExecution-mode energy (FLOPs/slot):")
    print(fmt_row("concurrent (both)", f"{f_conc:.3g}"))
    print(fmt_row("selected-only (MMSE)", f"{f_sel_mmse:.3g}",
                  f"saves {(1 - f_sel_mmse / f_conc) * 100:.0f}%"))

    # gated execution: the power proxy as a function of the AI share.  The
    # executed-cost accounting makes per-UE compute a function of the
    # realized mix (f_mmse + share * f_ai), which the calibrated model maps
    # to the paper's power/utilization envelope — the Fig.-11-style
    # power-vs-mode tradeoff, continuously in the share.
    from repro.core.expert_bank import BankOutput, ExpertBank
    import jax.numpy as jnp

    bank_g = ExpertBank(
        pipe_c.bank.experts, default_mode=1,
        execution_mode=ExecutionMode.GATED,
    )
    n_ues = 16
    print("\nGated execution: power proxy vs AI share (good conditions, "
          f"{n_ues} UEs):")
    print(fmt_row("AI share", "exec FLOPs/UE-slot", "util", "power W"))
    gated_rows = {}
    for n_ai in (0, 1, 4, 8, 16):
        counts = jnp.asarray([n_ai, n_ues], jnp.int32)
        out = BankOutput(selected=None, all_outputs=None,
                         mode=jnp.zeros((n_ues,), jnp.int32),
                         executed_ue=counts)
        per_ue = float(bank_g.executed_flops(out)) / n_ues
        u, p = model(per_ue, "good")
        share = n_ai / n_ues
        print(fmt_row(f"{share:.3g}", f"{per_ue:.3g}", f"{u*100:.0f}%",
                      f"{p:.1f}"))
        gated_rows[share] = p
    print(f"1-in-16 AI fleet saves "
          f"{gated_rows[1.0] - gated_rows[1/16]:.1f} W/UE-slot envelope vs "
          "all-AI (concurrent pays the all-AI cost regardless)")

    return {
        "power_saving_good_w": d_good,
        "util_saving_good_pp": du_good,
        "power_gap_poor_w": d_poor,
        "selected_only_flop_saving": 1 - f_sel_mmse / f_conc,
        "gated_power_by_share_w": gated_rows,
    }


if __name__ == "__main__":
    run()
