"""Paper Fig. 10: CDFs of six KPMs, AI vs MMSE x good/poor conditions.

Reports distribution percentiles and the headline median gains the paper
quotes (PHY +5.32%/+7.23%, MAC +6.45%/+9.23%, MCS 20v19 / 12v11).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import campaign, fmt_row, median

FIG10_KPMS = ("phy_throughput", "mcs_index", "lcid4_rx_bytes",
              "mac_throughput", "pdu_length", "rsrp")


def _cdf_pcts(x, pcts=(10, 25, 50, 75, 90)):
    return {p: float(np.percentile(x, p)) for p in pcts}


def run() -> dict:
    print("\n== KPM CDFs: AI vs MMSE x good/poor (paper Fig. 10) ==")
    out = {}
    for kpm in FIG10_KPMS:
        print(f"\n{kpm}:")
        print(fmt_row("condition", "expert", "p10", "p50", "p90"))
        for cond in ("good", "poor"):
            for mode, name in ((0, "AI"), (1, "MMSE")):
                pc = _cdf_pcts(campaign(mode, cond)[kpm])
                print(fmt_row(cond, name, f"{pc[10]:.4g}", f"{pc[50]:.4g}",
                              f"{pc[90]:.4g}"))
                out[(kpm, cond, name)] = pc

    print("\n== Headline median gains (AI over MMSE) ==")
    print(fmt_row("metric", "good (ours)", "good (paper)", "poor (ours)",
                  "poor (paper)"))
    headline = {}
    for kpm, paper_g, paper_p in (
        ("phy_throughput", "+5.32%", "+7.23%"),
        ("mac_throughput", "+6.45%", "+9.23%"),
    ):
        gains = {}
        for cond in ("good", "poor"):
            ai = median(campaign(0, cond)[kpm])
            mm = median(campaign(1, cond)[kpm])
            gains[cond] = 100.0 * (ai - mm) / mm
        print(fmt_row(kpm, f"{gains['good']:+.2f}%", paper_g,
                      f"{gains['poor']:+.2f}%", paper_p))
        headline[kpm] = gains
    for cond in ("good", "poor"):
        mcs_ai = median(campaign(0, cond)["mcs_index"])
        mcs_mm = median(campaign(1, cond)["mcs_index"])
        print(fmt_row(f"mcs_index ({cond})", f"{mcs_ai:.0f} vs {mcs_mm:.0f}",
                      "20 vs 19" if cond == "good" else "12 vs 11", "", ""))

    # the paper's RSRP observation: noise inflates MMSE-path RSRP under poor
    r_ai = median(campaign(0, "poor")["rsrp"])
    r_mm = median(campaign(1, "poor")["rsrp"])
    print(fmt_row("rsrp poor (AI/MMSE)", f"{r_ai:.3f}/{r_mm:.3f}",
                  "MMSE inflated (paper 4.3)", "", ""))
    return {"headline": headline}


if __name__ == "__main__":
    run()
