"""Session-API section: the declarative campaign surface, checked end to end.

Every invocation (a) round-trips a ``CampaignSpec`` through JSON before
running it — campaigns are reproducible from their provenance string by
construction — and (b) asserts the session's dispatch is *the same program*
as the legacy entry points: closed-loop modes bitwise-equal to a direct
``run_closed_loop`` on the session's own components, and a per-UE
heterogeneous campaign bitwise-equal to its per-UE host replay.  Doubles as
the CI smoke check for the session layer; the returned dict feeds the
``--json`` perf snapshot (serialized spec + hash == benchmark provenance).
"""

from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.core.session import (
    ArchesSession,
    CampaignSpec,
    PolicySpec,
    SwitchSpec,
    spec_hash,
)


def run(n_slots: int = 20, n_ues: int = 4) -> dict:
    poor = (("poor_start", n_slots // 3), ("poor_end", 2 * n_slots // 3))

    # -- closed loop through the session vs the legacy engine call ----------
    spec = CampaignSpec(
        path="closed_loop",
        scenario="good_poor_good",
        scenario_args=poor,
        n_ues=n_ues,
        n_slots=n_slots,
        seed=7,
        policies=(PolicySpec(kind="tree", depth=2),),
        switch=SwitchSpec(window_slots=2),
    )
    restored = CampaignSpec.from_json(spec.to_json())
    assert restored == spec, "CampaignSpec JSON round trip drifted"
    session = ArchesSession(restored)

    t0 = time.perf_counter()
    hist = session.run()  # BatchedRunHistory holds host arrays: already synced
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    hist = session.run()
    warm_s = time.perf_counter() - t0
    rate = n_slots * n_ues / warm_s

    _, sw, traj = session.engine.run_closed_loop(
        session.schedule,
        session.device_policy,
        restored.switch.to_config(restored.feature_names),
        n_slots=n_slots,
        n_ues=n_ues,
        key=jax.random.PRNGKey(restored.seed),
    )
    assert np.array_equal(hist.modes, np.asarray(traj["active_mode"])), (
        "session closed loop != legacy run_closed_loop"
    )

    # -- per-UE heterogeneous campaign vs its host replay -------------------
    hetero = CampaignSpec.from_json(CampaignSpec(
        path="closed_loop",
        scenario="mixed_cell",
        n_ues=n_ues,
        n_slots=n_slots,
        seed=1,
        policies=(
            PolicySpec(kind="threshold", feature="snr", threshold=18.0,
                       hysteresis=2.0),
            # per-UE campaign: the tree trains on good_poor_good with its
            # window scaled into the horizon (two-class labels guaranteed)
            PolicySpec(kind="tree", depth=2),
        ),
        policy_assignment=tuple(u % 2 for u in range(n_ues)),
        switch=SwitchSpec(window_slots=2),
    ).to_json())
    hsession = ArchesSession(hetero)
    hhist = hsession.run()
    replay = hsession.host_replay(hhist)
    assert np.array_equal(hhist.modes, replay["active_mode"]), (
        "per-UE heterogeneous campaign != per-UE host replay"
    )

    print(f"closed-loop session:   {rate:8.1f} slot-UEs/s warm "
          f"(cold {cold_s:.2f}s incl. policy training + compile)")
    print(f"spec hash:             {spec_hash(spec)}")
    print(f"legacy equivalence:    bitwise (closed loop, {n_slots}x{n_ues})")
    print(f"per-UE heterogeneity:  bitwise vs host replay "
          f"({len(hetero.policies)} policies over {n_ues} UEs; "
          f"switches/UE {hhist.n_switches.tolist()})")
    return {
        "spec": json.loads(spec.to_json()),
        "spec_hash": spec_hash(spec),
        "session_slot_ues_per_s": rate,
        "hetero_spec_hash": spec_hash(hetero),
    }
