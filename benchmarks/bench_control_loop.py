"""Paper 6.1: end-to-end control-loop latency decomposition.

The framework overhead (shm copies + ZeroMQ) is carried as the paper's
measured constant; policy inference and switch-kernel terms come from this
host's measurements.  The decomposition and the slot-boundary semantics are
the reproducible part; the absolute 140 us belongs to the GH200.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.bench_switch import run as switch_run
from benchmarks.common import fmt_row
from repro.core.dapp import ControlLoopLatency, DApp, connect_dapp
from repro.core.e3 import E3Agent, E3IndicationMessage


def run(switch_stats: dict | None = None) -> dict:
    stats = switch_stats or switch_run()
    lat = ControlLoopLatency()

    print("\n== End-to-end control loop (paper 6.1) ==")
    print(fmt_row("stage", "paper (us)", "this host (us)"))
    print(fmt_row("framework overhead", "135", "135 (modeled)"))
    print(fmt_row("policy inference", "0.41", f"{stats['t_tree_us']:.2f}"))
    print(fmt_row("switch kernel", "3.36-4.89",
                  f"{stats['t_noop_us']:.1f}-{stats['t_copy_us']:.1f}"))
    e2e_paper = lat.end_to_end_us(1)
    print(fmt_row("total (MMSE path)", f"{e2e_paper:.1f} (~140)", "-"))

    # full-loop wall time through the actual E3 + dApp objects (host only)
    agent = E3Agent()
    dapp = DApp(lambda x: int(x[0] > 0), ["q"], window_slots=1)
    connect_dapp(agent, dapp)
    t0 = time.perf_counter()
    n = 2000
    for slot in range(n):
        agent.indicate(E3IndicationMessage(slot=slot, source="aerial",
                                           kpms={"q": float(slot % 3)}))
        agent.poll_control()
    loop_us = (time.perf_counter() - t0) / n * 1e6
    print(fmt_row("E3 transport emulation", "-", f"{loop_us:.1f}"))

    # timing semantics: decisions apply at the NEXT slot boundary
    print(fmt_row("decision visibility", "slot n -> n+1", "slot n -> n+1"))
    return {"e2e_paper_model_us": e2e_paper, "e3_emulation_us": loop_us,
            **stats}


if __name__ == "__main__":
    run()
