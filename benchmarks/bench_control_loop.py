"""Paper 6.1: end-to-end control-loop latency decomposition.

The framework overhead (shm copies + ZeroMQ) is carried as the paper's
measured constant; policy inference and switch-kernel terms come from this
host's measurements.  The decomposition and the slot-boundary semantics are
the reproducible part; the absolute 140 us belongs to the GH200.

``run_in_scan`` benchmarks the *compiled* alternative: the same policy's
decision path folded into the batched slot scan (``run_closed_loop``), with
zero host hops per decision — reported as slots/s with the policy on vs the
open-loop mode schedule, and the amortized per-slot decision overhead.
Every invocation also asserts the device-decided modes bitwise-match the
host replay (the loop-equivalence contract), so the benchmark doubles as a
smoke check.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.bench_switch import run as switch_run
from benchmarks.common import fmt_row
from repro.core.dapp import ControlLoopLatency, DApp, connect_dapp
from repro.core.e3 import E3Agent, E3IndicationMessage


def run(switch_stats: dict | None = None) -> dict:
    stats = switch_stats or switch_run()
    lat = ControlLoopLatency()

    print("\n== End-to-end control loop (paper 6.1) ==")
    print(fmt_row("stage", "paper (us)", "this host (us)"))
    print(fmt_row("framework overhead", "135", "135 (modeled)"))
    print(fmt_row("policy inference", "0.41", f"{stats['t_tree_us']:.2f}"))
    print(fmt_row("switch kernel", "3.36-4.89",
                  f"{stats['t_noop_us']:.1f}-{stats['t_copy_us']:.1f}"))
    e2e_paper = lat.end_to_end_us(1)
    print(fmt_row("total (MMSE path)", f"{e2e_paper:.1f} (~140)", "-"))

    # full-loop wall time through the actual E3 + dApp objects (host only)
    agent = E3Agent()
    dapp = DApp(lambda x: int(x[0] > 0), ["q"], window_slots=1)
    connect_dapp(agent, dapp)
    t0 = time.perf_counter()
    n = 2000
    for slot in range(n):
        agent.indicate(E3IndicationMessage(slot=slot, source="aerial",
                                           kpms={"q": float(slot % 3)}))
        agent.poll_control()
    loop_us = (time.perf_counter() - t0) / n * 1e6
    print(fmt_row("E3 transport emulation", "-", f"{loop_us:.1f}"))

    # timing semantics: decisions apply at the NEXT slot boundary
    print(fmt_row("decision visibility", "slot n -> n+1", "slot n -> n+1"))

    # in-scan closed loop: the same decision path, compiled into the scan
    in_scan = run_in_scan()
    return {"e2e_paper_model_us": e2e_paper, "e3_emulation_us": loop_us,
            **stats, **{f"in_scan_{k}": v for k, v in in_scan.items()}}


def run_in_scan(n_slots: int = 48, n_ues: int = 8,
                window_slots: int = 4) -> dict:
    """In-scan closed-loop switching vs open-loop schedule (device decisions).

    Trains a tiny depth-2 tree from profiled telemetry, then times the
    batched engine twice over the same campaign: open loop (precomputed mode
    grid) and closed loop (policy + switch register inside the scan).  The
    delta, amortized per slot, is the whole in-scan control loop — window
    push, tree inference, hysteresis, register — with no framework overhead
    term at all.  Asserts device decisions == host replay before reporting.
    """
    from benchmarks.common import NET, SLOT_CFG, get_ai_params
    from repro.core.closed_loop import SwitchConfig, host_replay_closed_loop
    from repro.core.policy import profile_and_fit_tree
    from repro.core.telemetry import SELECTED_KPMS, trajectory_kpm_matrix
    from repro.phy.pipeline import BatchedPuschPipeline
    from repro.phy.scenario import good_poor_good_schedule

    params, _ = get_ai_params()
    engine = BatchedPuschPipeline(SLOT_CFG, params, net=NET)
    schedule = good_poor_good_schedule(
        poor_start=n_slots // 3, poor_end=2 * n_slots // 3
    )

    # tiny policy from profiled telemetry (both experts, labelled slots)
    policy = profile_and_fit_tree(
        engine, schedule, n_slots=n_slots, n_ues=2, depth=2
    )
    sw_cfg = SwitchConfig(feature_names=SELECTED_KPMS,
                          window_slots=window_slots)
    device = policy.to_device()
    ue_keys = jax.random.split(jax.random.PRNGKey(7), n_ues)

    def timed(fn):
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0])
        return time.perf_counter() - t0, out

    t_open, _ = timed(lambda: engine.run(
        schedule, 1, n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys
    )[1])
    t_closed, traj = timed(lambda: engine.run_closed_loop(
        schedule, device, sw_cfg,
        n_slots=n_slots, n_ues=n_ues, ue_keys=ue_keys,
    )[2])

    # the equivalence contract: device loop == host replay, bitwise
    feats = np.asarray(trajectory_kpm_matrix(traj["kpms"], SELECTED_KPMS))
    replay = host_replay_closed_loop(policy, feats, sw_cfg)
    modes = np.asarray(traj["active_mode"])
    if not (np.array_equal(modes, replay["active_mode"])
            and np.array_equal(np.asarray(traj["raw_decision"]),
                               replay["raw_decision"])):
        raise AssertionError("device-decided modes != host replay")

    open_rate = n_slots * n_ues / t_open
    closed_rate = n_slots * n_ues / t_closed
    # clamp: on tiny configs timing noise can make the closed loop "faster"
    decide_us = max((t_closed - t_open) / n_slots * 1e6, 0.0)  # all UEs/slot
    lat = ControlLoopLatency()
    print("\n== In-scan closed loop (device-side policy + register) ==")
    print(fmt_row("config", f"{n_ues} UEs x {n_slots} slots",
                  f"window={window_slots}"))
    print(fmt_row("open-loop schedule", f"{open_rate:.1f} slot-UEs/s"))
    print(fmt_row("closed loop (policy on)", f"{closed_rate:.1f} slot-UEs/s"))
    print(fmt_row("in-scan decision", f"{decide_us:.1f} us/slot",
                  f"({decide_us / n_ues:.2f}/UE, all host hops gone)"))
    print(fmt_row("host loop (paper model)", f"{lat.end_to_end_us(1):.1f} us/decision",
                  "135 us framework + tree + switch"))
    print(fmt_row("device == host replay", "yes (bitwise)"))
    return {
        "open_rate": open_rate,
        "closed_rate": closed_rate,
        "decide_us_per_slot": decide_us,
        "equivalent": True,
    }


if __name__ == "__main__":
    run()
