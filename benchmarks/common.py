"""Shared benchmark substrate: standard slot config, trained AI expert,
per-condition slot campaigns, artifact caching.

Every paper-figure benchmark draws from the same campaign data so numbers are
mutually consistent (one "testbed", many analyses) — mirroring how the paper
derives Figs. 8-11 from one X5G measurement campaign.
"""

from __future__ import annotations

import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import SELECTED_KPMS
from repro.phy import dmrs as D
from repro.phy.ai_estimator import AiEstimatorConfig, train_ai_estimator
from repro.phy.channel import ChannelConfig, apply_channel, simulate_slot_channel
from repro.phy.estimators import ls_estimate
from repro.phy.nr import SlotConfig
from repro.phy.pipeline import LinkState, PuschPipeline
from repro.phy.scenario import GOOD, POOR

ART_DIR = os.path.join(os.path.dirname(__file__), "_artifacts")

# The standard benchmark testbed: one UE, 24 PRB, 4 RX antennas (paper: 106
# PRB on X5G; reduced for CPU wall-time, all derived ratios carry over).
SLOT_CFG = SlotConfig(n_prb=24)
NET = AiEstimatorConfig(channels=32, n_res_blocks=4)
TRAIN_STEPS = int(os.environ.get("ARCHES_BENCH_TRAIN_STEPS", "4000"))
N_SLOTS = int(os.environ.get("ARCHES_BENCH_SLOTS", "240"))


def _train_sample_fn(cfg: SlotConfig):
    """Mixture-of-conditions sampler: random SNR / doppler / interference.

    The Wiener filter's fixed priors are mismatched across this mixture,
    which is exactly the regime where a learned estimator wins (paper 5.1).
    """
    pilots = D.dmrs_sequence(cfg)
    zero_data = jnp.zeros((cfg.n_data_re(),), jnp.complex64)
    dmrs_idx = jnp.asarray(cfg.dmrs_symbols)

    @jax.jit
    def sample(key):
        k1, k2, k3 = jax.random.split(key, 3)
        snr = jax.random.uniform(k3, (), minval=5.0, maxval=14.0)
        # half the draws carry in-band interference (paper Fig. 7b)
        interf = jax.random.bernoulli(jax.random.fold_in(k3, 1), 0.5)
        inr = jax.random.uniform(jax.random.fold_in(k3, 2), (), minval=12.0, maxval=26.0)
        # unit-amplitude template (snr 0 dB, inr 0 dB -> amp == 1), rescaled
        # per-draw to the sampled operating point below.  The template carries
        # the pilot-contamination structure of the POOR scenario.
        ch = ChannelConfig(
            snr_db=0.0, interference=True, inr_db=0.0,
            interference_symbol_duty=3.0 / 14.0, dmrs_collision=True,
        )
        fields = dict(simulate_slot_channel(k1, cfg, ch))
        noise_var = 10.0 ** (-snr / 10.0)
        fields["noise_var"] = jnp.asarray(noise_var, jnp.float32)
        fields["interference"] = fields["interference"] * jnp.where(
            interf, jnp.sqrt(noise_var * 10.0 ** (inr / 10.0)), 0.0
        ).astype(jnp.float32)
        grid = D.map_slot_grid(cfg, zero_data, pilots)
        rx = apply_channel(k2, grid, fields)
        h_ls = ls_estimate(cfg, rx, pilots)
        h_true = fields["h"][:, :, :, dmrs_idx]
        return h_ls, h_true

    return sample


def get_ai_params(force: bool = False):
    """Train (or load cached) Expert B for the benchmark testbed."""
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, f"ai_params_{SLOT_CFG.n_prb}prb_{TRAIN_STEPS}.pkl")
    if os.path.exists(path) and not force:
        with open(path, "rb") as f:
            return pickle.load(f)
    t0 = time.time()
    params, losses = train_ai_estimator(
        jax.random.PRNGKey(0),
        SLOT_CFG,
        _train_sample_fn(SLOT_CFG),
        net=NET,
        steps=TRAIN_STEPS,
        lr=2e-3,
    )
    params = jax.device_get(params)
    meta = {"steps": TRAIN_STEPS, "loss_first": losses[0], "loss_last": losses[-1],
            "train_s": time.time() - t0}
    with open(path, "wb") as f:
        pickle.dump((params, meta), f)
    return params, meta


def get_pipeline(**kw) -> PuschPipeline:
    params, _ = get_ai_params()
    return PuschPipeline(SLOT_CFG, params, net=NET, **kw)


# -- slot campaigns ---------------------------------------------------------------


def run_campaign(
    pipe: PuschPipeline,
    mode: int,
    ch: ChannelConfig,
    *,
    n_slots: int = N_SLOTS,
    seed: int = 0,
    warmup: int = 40,
) -> dict[str, np.ndarray]:
    """Fixed-mode slot campaign; returns per-slot KPM arrays (post-warmup)."""
    link = LinkState()
    rows = []
    for i in range(n_slots):
        link, out, kpms = pipe.run_slot(
            jax.random.PRNGKey(seed * 100_000 + i), mode, link, ch
        )
        if i >= warmup:
            rows.append({**kpms["aerial"], **kpms["oai"],
                         "tb_ok": out["tb_ok"], "mcs": out["mcs"]})
    return {k: np.asarray([r[k] for r in rows]) for k in rows[0]}


_campaign_cache: dict = {}


def campaign(mode: int, condition: str, seed: int = 0) -> dict[str, np.ndarray]:
    """Cached (mode x condition) campaign — the shared measurement data."""
    key = (mode, condition, seed, N_SLOTS)
    if key not in _campaign_cache:
        path = os.path.join(
            ART_DIR, f"campaign_m{mode}_{condition}_s{seed}_{N_SLOTS}.npz"
        )
        if os.path.exists(path):
            data = dict(np.load(path))
        else:
            pipe = get_pipeline()
            ch = {"good": GOOD, "poor": POOR}[condition]
            data = run_campaign(pipe, mode, ch, seed=seed)
            os.makedirs(ART_DIR, exist_ok=True)
            np.savez(path, **data)
        _campaign_cache[key] = data
    return _campaign_cache[key]


def median(x) -> float:
    return float(np.median(np.asarray(x)))


def fmt_row(*cols, w=22) -> str:
    return " | ".join(str(c)[:w].ljust(w) for c in cols)
