"""Benchmark driver: one section per paper table/figure + roofline.

Usage:  PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]

``--smoke`` is the CI fast path: tiny expert training, three sections only
(switch-kernel runtimes + batched multi-UE engine + closed-loop device/host
equivalence), exits non-zero on any failure.  Finishes in minutes where the
full sweep takes an hour.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke check (switch + batched engine)")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()

    if args.smoke:
        # must precede the benchmarks.common import (module-level env reads)
        os.environ.setdefault("ARCHES_BENCH_TRAIN_STEPS", "40")
        os.environ.setdefault("ARCHES_BENCH_SLOTS", "40")

    from benchmarks import (
        bench_control_loop,
        bench_kpm_cdfs,
        bench_methodology,
        bench_policy,
        bench_resources,
        bench_switch,
        bench_timeseries,
        roofline,
    )

    if args.smoke:
        sections = [
            ("Fig. 8  switching-mechanism runtimes", bench_switch.run, {}),
            ("Batched multi-UE engine (smoke)", bench_timeseries.run_batched,
             {"n_slots": 24, "n_ues": 4, "host_probe_slots": 6,
              "check_identity": False}),
            # tiny policy, 8 slots: raises unless device-decided modes
            # bitwise-match the host replay (the loop-equivalence contract)
            ("Closed-loop equivalence (smoke)", bench_control_loop.run_in_scan,
             {"n_slots": 8, "n_ues": 2, "window_slots": 2}),
        ]
    else:
        sections = [
            ("Fig. 8  switching-mechanism runtimes", bench_switch.run, {}),
            ("6.1     control-loop latency", None, {}),  # uses Fig. 8 stats
            ("Fig. 4+5 policy-design methodology", bench_methodology.run,
             {"n_trials": 2 if args.fast else 4,
              "rho_step": 0.5 if args.fast else 0.2}),
            ("Table 1 decision-tree performance", bench_policy.run, {}),
            ("Fig. 9  throughput time series", bench_timeseries.run,
             {"n_phase": 10 if args.fast else None}),
            ("Batched multi-UE engine", bench_timeseries.run_batched,
             {"n_slots": 60 if args.fast else 100,
              "n_ues": 8 if args.fast else 16}),
            ("Fig. 10 KPM CDFs", bench_kpm_cdfs.run, {}),
            ("Fig. 11 GPU resources proxy", bench_resources.run, {}),
            ("Roofline (from dry-run)", roofline.run,
             {"path": args.dryrun_json}),
        ]

    results, failures = {}, []
    switch_stats = None
    for title, fn, kw in sections:
        print("\n" + "=" * 78)
        print("##", title)
        print("=" * 78)
        t0 = time.time()
        try:
            if title.startswith("6.1"):
                out = bench_control_loop.run(switch_stats)
            else:
                out = fn(**kw)
            if title.startswith("Fig. 8"):
                switch_stats = out
            results[title] = "ok"
        except Exception:
            traceback.print_exc()
            failures.append(title)
            results[title] = "FAILED"
        print(f"[{title.split()[0]}] {results[title]} in {time.time()-t0:.0f}s")

    print("\n" + "=" * 78)
    print("## Summary")
    for title, status in results.items():
        print(f"  {status:7s} {title}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
