"""Benchmark driver: one section per paper table/figure + roofline.

Usage:  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps (CI smoke)")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()

    from benchmarks import (
        bench_control_loop,
        bench_kpm_cdfs,
        bench_methodology,
        bench_policy,
        bench_resources,
        bench_switch,
        bench_timeseries,
        roofline,
    )

    sections = [
        ("Fig. 8  switching-mechanism runtimes", bench_switch.run, {}),
        ("6.1     control-loop latency", None, {}),  # uses Fig. 8 stats
        ("Fig. 4+5 policy-design methodology", bench_methodology.run,
         {"n_trials": 2 if args.fast else 4,
          "rho_step": 0.5 if args.fast else 0.2}),
        ("Table 1 decision-tree performance", bench_policy.run, {}),
        ("Fig. 9  throughput time series", bench_timeseries.run,
         {"n_phase": 10 if args.fast else None}),
        ("Fig. 10 KPM CDFs", bench_kpm_cdfs.run, {}),
        ("Fig. 11 GPU resources proxy", bench_resources.run, {}),
        ("Roofline (from dry-run)", roofline.run,
         {"path": args.dryrun_json}),
    ]

    results, failures = {}, []
    switch_stats = None
    for title, fn, kw in sections:
        print("\n" + "=" * 78)
        print("##", title)
        print("=" * 78)
        t0 = time.time()
        try:
            if title.startswith("6.1"):
                out = bench_control_loop.run(switch_stats)
            else:
                out = fn(**kw)
            if title.startswith("Fig. 8"):
                switch_stats = out
            results[title] = "ok"
        except Exception:
            traceback.print_exc()
            failures.append(title)
            results[title] = "FAILED"
        print(f"[{title.split()[0]}] {results[title]} in {time.time()-t0:.0f}s")

    print("\n" + "=" * 78)
    print("## Summary")
    for title, status in results.items():
        print(f"  {status:7s} {title}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
