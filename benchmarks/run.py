"""Benchmark driver: one section per paper table/figure + roofline.

Usage:  PYTHONPATH=src python -m benchmarks.run [--fast | --smoke]
                                                [--json BENCH_<tag>.json]

``--smoke`` is the CI fast path: tiny expert training, nine sections only
(switch-kernel runtimes + batched multi-UE engine + closed-loop device/host
equivalence + gated-execution contract + session-API dispatch/provenance +
sharded-engine parity/scaling + streaming-churn zero-churn equivalence +
fault-injection/crash-resume + campaign-service API/drain-resume),
exits non-zero on any failure.  Finishes in minutes where the full sweep
takes an hour.

``--json PATH`` additionally writes a machine-readable perf snapshot —
slot-UEs/s, in-scan decision latency, executed-FLOPs-per-slot across AI
shares {0, 1/16, 1/2, 1}, and the sharded-engine parity/scaling row — so
the repo's bench trajectory accumulates across PRs.  The snapshot embeds
the serialized ``CampaignSpec`` + its ``spec_hash`` from the session
section, so every perf number carries the exact campaign it was measured
on.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback


def _jax_backend() -> str:
    """Default-backend name, without importing jax before env setup."""
    import jax

    return jax.default_backend()


def _json_payload(outs: dict) -> dict:
    """Assemble the perf-trajectory snapshot from section outputs."""
    payload: dict = {"schema": "arches-bench-v5", "time": time.strftime(
        "%Y-%m-%dT%H:%M:%S")}
    # host fingerprint: check_snapshot only compares absolute rates when
    # these match (cross-host wall-clock deltas are meaningless)
    payload["host"] = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "cpu_count": os.cpu_count(),
        "jax_backend": _jax_backend(),
    }
    batched = outs.get("batched")
    if batched:
        payload["slot_ues_per_s"] = {
            "host_loop": batched["host_rate"],
            "scan_engine": batched["batched_rate"],
            "speedup": batched["speedup"],
        }
    in_scan = outs.get("in_scan")
    if in_scan:
        payload["in_scan_decision_us_per_slot"] = in_scan["decide_us_per_slot"]
        payload["closed_loop_slot_ues_per_s"] = in_scan["closed_rate"]
    gated = outs.get("gated")
    if gated:
        payload["gated"] = {
            share: {
                "executed_flops_per_slot": row["executed_flops_per_slot"],
                "gated_slot_ues_per_s": row["gated_slot_ues_per_s"],
                "concurrent_slot_ues_per_s": row["concurrent_slot_ues_per_s"],
                "speedup_vs_concurrent": row["speedup"],
                "fused_slot_ues_per_s": row["fused_slot_ues_per_s"],
                "fused_speedup_vs_unfused": row["fused_speedup_vs_unfused"],
                # true off-TPU: the ref fallback is the same XLA program,
                # so the fused timing is the unfused one (not re-measured)
                "fused_shares_program_with_unfused":
                    row["fused_shares_program_with_unfused"],
                "bf16_slot_ues_per_s": row["bf16_slot_ues_per_s"],
                "bf16_audit_tripped": row["bf16_audit_tripped"],
            }
            for share, row in gated["by_share"].items()
        }
    session = outs.get("session")
    if session:
        # benchmark provenance: the exact campaign the numbers came from
        payload["campaign_spec"] = session["spec"]
        payload["campaign_spec_hash"] = session["spec_hash"]
        payload["session_slot_ues_per_s"] = session["session_slot_ues_per_s"]
    sharded = outs.get("sharded")
    if sharded:
        payload["sharded"] = {
            "parity": sharded["parity"],
            "one_device_slot_ues_per_s":
                sharded["one_device_slot_ues_per_s"],
            "forced_shards": sharded["forced"]["n_shards"],
            "forced_slot_ues_per_s": sharded["forced"]["slot_ues_per_s"],
        }
    streaming = outs.get("streaming")
    if streaming:
        # v2 schema: the epoch-chunked churn-campaign rates; v5 adds the
        # pipelined-executor rates, the per-segment wall-time breakdown,
        # and the O(segment) delta-checkpoint byte measurement
        payload["streaming"] = {
            "zero_churn_equal": streaming["zero_churn_equal"],
            "streaming_slot_ues_per_s":
                streaming["streaming_slot_ues_per_s"],
            "monolithic_slot_ues_per_s":
                streaming["monolithic_slot_ues_per_s"],
            "churn_resident_slot_ues_per_s":
                streaming["churn_resident_slot_ues_per_s"],
            "n_segments": streaming["n_segments"],
            "serial_checkpointed_slot_ues_per_s":
                streaming["serial_checkpointed_slot_ues_per_s"],
            "pipelined_checkpointed_slot_ues_per_s":
                streaming["pipelined_checkpointed_slot_ues_per_s"],
            "pipeline_speedup": streaming["pipeline_speedup"],
            "segment_breakdown_s": streaming["segment_breakdown_s"],
            "delta_ckpt_bytes_per_segment":
                streaming["delta_ckpt_bytes_per_segment"],
            "delta_bytes_length_invariant":
                streaming["delta_bytes_length_invariant"],
        }
    faults = outs.get("faults")
    if faults:
        # v3 schema: fault-injection replay + crash-resume rates
        payload["faults"] = {
            "fault_replay_equal": faults["fault_replay_equal"],
            "resume_equal": faults["resume_equal"],
            "fault_closed_slot_ues_per_s":
                faults["fault_closed_slot_ues_per_s"],
            "checkpointed_slot_ues_per_s":
                faults["checkpointed_slot_ues_per_s"],
            "health_tripped_slot_ues": faults["health_tripped_slot_ues"],
            "quarantined_slot_ues": faults["quarantined_slot_ues"],
        }
    service = outs.get("service")
    if service:
        # v4 schema: the resident campaign service (API-driven campaigns,
        # telemetry export, drain/resume through the service path)
        payload["service"] = {
            "zero_churn_service_equal": service["zero_churn_service_equal"],
            "drain_resume_equal": service["drain_resume_equal"],
            "status_transitions": service["status_transitions"],
            "n_segments": service["n_segments"],
            "telemetry_exported": service["telemetry_exported"],
            "telemetry_dropped": service["telemetry_dropped"],
            "service_campaign_wall_s": service["service_campaign_wall_s"],
            "slot_ues_per_s_cold": service["slot_ues_per_s_cold"],
            "direct_streaming_slot_ues_per_s":
                service["direct_streaming_slot_ues_per_s"],
        }
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller sweeps")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal CI smoke check (switch + batched engine)")
    ap.add_argument("--json", default=None, metavar="BENCH_<tag>.json",
                    help="write a machine-readable perf snapshot")
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()

    if args.smoke:
        # must precede the benchmarks.common import (module-level env reads)
        os.environ.setdefault("ARCHES_BENCH_TRAIN_STEPS", "40")
        os.environ.setdefault("ARCHES_BENCH_SLOTS", "40")

    from benchmarks import (
        bench_control_loop,
        bench_faults,
        bench_gated,
        bench_kpm_cdfs,
        bench_methodology,
        bench_policy,
        bench_resources,
        bench_session,
        bench_service,
        bench_sharded,
        bench_streaming,
        bench_switch,
        bench_timeseries,
        roofline,
    )

    # (key, title, fn, kwargs): ``key`` names the section's output for the
    # --json payload (None == not part of the snapshot).
    if args.smoke:
        sections = [
            (None, "Fig. 8  switching-mechanism runtimes", bench_switch.run, {}),
            ("batched", "Batched multi-UE engine (smoke)",
             bench_timeseries.run_batched,
             {"n_slots": 24, "n_ues": 4, "host_probe_slots": 6,
              "check_identity": False}),
            # tiny policy, 8 slots: raises unless device-decided modes
            # bitwise-match the host replay (the loop-equivalence contract)
            ("in_scan", "Closed-loop equivalence (smoke)",
             bench_control_loop.run_in_scan,
             {"n_slots": 8, "n_ues": 2, "window_slots": 2}),
            # raises unless gated == concurrent bitwise, fused == unfused
            # bitwise, the bf16 audit stays quiet, and executed FLOPs at AI
            # share 0 equal the MMSE-only cost model.  n_ues=8 keeps the
            # 1/16 share distinct from 1/4 (ceil -> 1 vs 2 AI UEs); the
            # share set matches the acceptance sweep {1/16, 1/4, 1}.
            # n_slots=32 / repeats=9: fused and unfused lower to
            # near-identical XLA:CPU programs, so the speedup columns need
            # long timed runs (scheduler jitter is fixed-size, its relative
            # weight falls with scan length) and min-of-repeats headroom.
            ("gated", "Gated execution (smoke)", bench_gated.run,
             {"n_slots": 32, "n_ues": 8,
              "shares": (0.0, 1.0 / 16.0, 0.25, 1.0), "repeats": 9}),
            # raises unless the declarative session reproduces the legacy
            # closed loop bitwise and a per-UE heterogeneous campaign
            # matches its per-UE host replay (spec JSON round-trip included)
            ("session", "Session API (smoke)", bench_session.run,
             {"n_slots": 12, "n_ues": 2}),
            # raises unless the sharded entry is bitwise-equal to the
            # unsharded engine on 1 device; also runs the same campaign on
            # a forced-8-shard CPU mesh (subprocess) for scaling numbers
            ("sharded", "Sharded multi-cell engine (smoke)",
             bench_sharded.run, {"n_slots": 10, "n_ues": 8}),
            # raises unless a zero-churn streaming run is bitwise-equal to
            # the monolithic session run on every leaf and a churn campaign
            # keeps the detached-sentinel / zero-cost accounting
            ("streaming", "Streaming churn campaigns (smoke)",
             bench_streaming.run,
             {"n_slots": 16, "n_ues": 4, "segment_slots": 8}),
            # raises unless a fault-injected closed loop (outage + NaN
            # corruption + telemetry loss) replays bitwise through the host
            # oracle and a killed-then-resumed streaming run is bitwise-
            # equal to the uninterrupted one on every leaf
            ("faults", "Fault injection + crash resume (smoke)",
             bench_faults.run,
             {"n_slots": 16, "n_ues": 4, "segment_slots": 8}),
            # raises unless a campaign submitted over the live HTTP API is
            # bitwise-equal to the monolithic run, its telemetry export is
            # lossless, and a drained-then-restarted service resumes a
            # churn campaign bitwise from its checkpoint
            ("service", "Campaign service (smoke)", bench_service.run,
             {"n_slots": 16, "n_ues": 4, "segment_slots": 4}),
        ]
    else:
        sections = [
            (None, "Fig. 8  switching-mechanism runtimes", bench_switch.run, {}),
            (None, "6.1     control-loop latency", None, {}),  # uses Fig. 8
            (None, "Fig. 4+5 policy-design methodology", bench_methodology.run,
             {"n_trials": 2 if args.fast else 4,
              "rho_step": 0.5 if args.fast else 0.2}),
            (None, "Table 1 decision-tree performance", bench_policy.run, {}),
            (None, "Fig. 9  throughput time series", bench_timeseries.run,
             {"n_phase": 10 if args.fast else None}),
            ("batched", "Batched multi-UE engine", bench_timeseries.run_batched,
             {"n_slots": 60 if args.fast else 100,
              "n_ues": 8 if args.fast else 16}),
            ("gated", "Gated expert execution", bench_gated.run,
             {"n_slots": 30 if args.fast else 60,
              "n_ues": 8 if args.fast else 16}),
            ("session", "Session API (declarative campaigns)",
             bench_session.run,
             {"n_slots": 24 if args.fast else 48,
              "n_ues": 4 if args.fast else 8}),
            ("sharded", "Sharded multi-cell engine",
             bench_sharded.run,
             {"n_slots": 16 if args.fast else 32,
              "n_ues": 8 if args.fast else 16}),
            ("streaming", "Streaming churn campaigns",
             bench_streaming.run,
             {"n_slots": 24 if args.fast else 48,
              "n_ues": 4 if args.fast else 8,
              "segment_slots": 8}),
            ("faults", "Fault injection + crash resume",
             bench_faults.run,
             {"n_slots": 24 if args.fast else 48,
              "n_ues": 4 if args.fast else 8,
              "segment_slots": 8}),
            ("service", "Campaign service (dispatch + API + drain/resume)",
             bench_service.run,
             {"n_slots": 24 if args.fast else 48,
              "n_ues": 4 if args.fast else 8,
              "segment_slots": 8}),
            (None, "Fig. 10 KPM CDFs", bench_kpm_cdfs.run, {}),
            (None, "Fig. 11 GPU resources proxy", bench_resources.run, {}),
            (None, "Roofline (from dry-run)", roofline.run,
             {"path": args.dryrun_json}),
        ]

    results, failures = {}, []
    json_outs: dict = {}
    switch_stats = None
    for key, title, fn, kw in sections:
        print("\n" + "=" * 78)
        print("##", title)
        print("=" * 78)
        t0 = time.time()
        try:
            if title.startswith("6.1"):
                out = bench_control_loop.run(switch_stats)
                json_outs["in_scan"] = {
                    f.removeprefix("in_scan_"): v
                    for f, v in out.items() if f.startswith("in_scan_")
                }
            else:
                out = fn(**kw)
            if title.startswith("Fig. 8"):
                switch_stats = out
            if key is not None:
                json_outs[key] = out
            results[title] = "ok"
        except Exception:
            traceback.print_exc()
            failures.append(title)
            results[title] = "FAILED"
        print(f"[{title.split()[0]}] {results[title]} in {time.time()-t0:.0f}s")

    print("\n" + "=" * 78)
    print("## Summary")
    for title, status in results.items():
        print(f"  {status:7s} {title}")

    if args.json:
        payload = _json_payload(json_outs)
        payload["failures"] = failures
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"\nwrote perf snapshot -> {args.json}")

    if args.smoke:
        # schema/regression gate: the committed snapshot must stay readable
        # by current tooling, and a fresh snapshot (when --json was given)
        # must not regress slot-UEs/s >20% on a comparable host
        from benchmarks import check_snapshot

        print("\n" + "=" * 78)
        print("## Snapshot schema/regression gate")
        print("=" * 78)
        rc = check_snapshot.check(
            check_snapshot.DEFAULT_BASELINE,
            candidate=args.json,
        )
        if rc:
            failures.append("Snapshot schema/regression gate")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
