"""Paper Fig. 8: runtime statistics of the switching mechanism components.

Measures wall-time on this host for: the switch kernel no-op path (mode=0),
the copy path (mode=1), decision-tree inference (single + batched), the MMSE
kernel, and the AI estimator — and reports the *structural* quantities that
transfer to the TPU target (bytes moved per path, FLOPs per expert, expected
path asymmetry). The paper's GH200 microseconds are printed alongside.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import NET, SLOT_CFG, fmt_row, get_ai_params
from repro.core.policy import DecisionTreePolicy, fit_decision_tree
from repro.kernels.switch_select import switch_select
from repro.phy.ai_estimator import ai_estimate_from_ls
from repro.phy.estimators import WienerInterpolator, estimator_flops
from repro.kernels.mmse_interp import mmse_interp
from repro.core.telemetry import SELECTED_KPMS


def _time(fn, *args, reps=30, warmup=3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run() -> dict:
    cfg = SLOT_CFG
    params, _ = get_ai_params()
    shape = (cfg.n_ant, cfg.n_layers, cfg.n_sc, cfg.n_dmrs_sym)
    key = jax.random.PRNGKey(0)
    h_ai = (jax.random.normal(key, shape) + 1j * jax.random.normal(key, shape)).astype(jnp.complex64)
    h_mmse = h_ai * 0.9

    sw = jax.jit(lambda m: switch_select(m, [h_ai, h_mmse]))
    t_noop = _time(sw, jnp.int32(0))
    t_copy = _time(sw, jnp.int32(1))

    # batched multi-UE switch: one kernel call routes 16 UEs independently
    n_ues = 16
    hb_ai = jnp.broadcast_to(h_ai[None], (n_ues,) + shape)
    hb_mmse = jnp.broadcast_to(h_mmse[None], (n_ues,) + shape)
    swb = jax.jit(lambda m: switch_select(m, [hb_ai, hb_mmse]))
    t_b_noop = _time(swb, jnp.zeros((n_ues,), jnp.int32))
    mixed = (jnp.arange(n_ues) % 2).astype(jnp.int32)
    t_b_mixed = _time(swb, mixed)

    # decision tree (trained on synthetic data, depth 2 x 10 KPMs, paper cfg)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(512, len(SELECTED_KPMS))).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.int32)
    tree = fit_decision_tree(X, y, depth=2)
    pol = DecisionTreePolicy(tree, SELECTED_KPMS)
    xj = jnp.asarray(X[0])
    t_tree = _time(lambda v: pol(v), xj)
    xb = jnp.asarray(X)
    t_tree_batch = _time(lambda v: pol.batch(v), xb) / len(X)

    # experts
    wi = WienerInterpolator.build(cfg)
    h_ls = (jax.random.normal(key, (cfg.n_ant, cfg.n_dmrs_sym, cfg.n_pilot_sc))
            + 1j * jax.random.normal(key, (cfg.n_ant, cfg.n_dmrs_sym, cfg.n_pilot_sc))
            ).astype(jnp.complex64)
    mmse_fn = jax.jit(lambda h: mmse_interp(h, wi.w))
    t_mmse = _time(mmse_fn, h_ls)
    ai_fn = jax.jit(lambda h: ai_estimate_from_ls(params, h))
    t_ai = _time(ai_fn, h_ls, reps=10)

    buf_bytes = int(np.prod(shape)) * 8  # complex64
    print("\n== Switching-mechanism runtimes (paper Fig. 8) ==")
    print(fmt_row("component", "this host (us)", "paper GH200 (us)"))
    print(fmt_row("switch kernel noop(AI)", f"{t_noop:.1f}", "3.36"))
    print(fmt_row("switch kernel copy(MMSE)", f"{t_copy:.1f}", "4.89"))
    print(fmt_row(f"batched x{n_ues} noop", f"{t_b_noop:.1f}",
                  f"({t_b_noop / n_ues:.2f}/UE)"))
    print(fmt_row(f"batched x{n_ues} mixed", f"{t_b_mixed:.1f}",
                  f"({t_b_mixed / n_ues:.2f}/UE)"))
    print(fmt_row("decision tree (single)", f"{t_tree:.2f}", "0.41"))
    print(fmt_row("decision tree (batched)", f"{t_tree_batch:.4f}", "-"))
    print(fmt_row("MMSE expert", f"{t_mmse:.1f}", "5.04"))
    print(fmt_row("AI expert", f"{t_ai:.1f}", "432"))
    print(fmt_row("AI/MMSE latency ratio", f"{t_ai/t_mmse:.1f}x", "85x"))
    print(fmt_row("switch buffer", f"{buf_bytes/1024:.0f} KiB", "-"))

    flops_ai = NET.flops(cfg)
    flops_mmse = estimator_flops(cfg)
    print(fmt_row("AI expert FLOPs/slot", f"{flops_ai:.3g}", "-"))
    print(fmt_row("MMSE expert FLOPs/slot", f"{flops_mmse:.3g}", "-"))
    print(fmt_row("AI/MMSE FLOP ratio", f"{flops_ai/flops_mmse:.1f}x", "-"))

    return {
        "t_noop_us": t_noop, "t_copy_us": t_copy,
        "t_batched_noop_us": t_b_noop, "t_batched_mixed_us": t_b_mixed,
        "t_tree_us": t_tree, "t_tree_batch_us": t_tree_batch,
        "t_mmse_us": t_mmse, "t_ai_us": t_ai,
        "ai_mmse_latency_ratio": t_ai / t_mmse,
        "ai_mmse_flop_ratio": flops_ai / flops_mmse,
    }


if __name__ == "__main__":
    run()
