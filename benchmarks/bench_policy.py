"""Paper Table 1: decision-tree classification performance.

Trains the depth-2 Gini tree on interference-labelled slot telemetry
(profiled under both experts, 80/20 split) and reports accuracy / precision /
specificity / F1, plus the top feature importances (paper 5.3).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import campaign, fmt_row
from repro.core.policy import (
    DecisionTreePolicy,
    classification_metrics,
    fit_decision_tree,
)
from repro.core.telemetry import SELECTED_KPMS


def build_dataset(seed_pairs=((0, 1), (2, 3))) -> tuple[np.ndarray, np.ndarray]:
    X, y = [], []
    for s_good, s_poor in seed_pairs:
        for mode in (0, 1):
            for cond, label, seed in (("good", 1, s_good), ("poor", 0, s_poor)):
                data = campaign(mode, cond, seed=seed)
                rows = np.stack([data[n] for n in SELECTED_KPMS], axis=1)
                X.append(rows)
                y.append(np.full(rows.shape[0], label))
    return np.concatenate(X).astype(np.float32), np.concatenate(y).astype(np.int32)


def run() -> dict:
    X, y = build_dataset()
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    X, y = X[perm], y[perm]
    n_train = int(0.8 * len(y))  # 80/20 split, as the paper
    tree = fit_decision_tree(X[:n_train], y[:n_train], depth=2)
    policy = DecisionTreePolicy(tree, SELECTED_KPMS)
    pred = np.asarray(policy.batch(X[n_train:]))
    m = classification_metrics(y[n_train:], pred)

    print("\n== Decision-tree performance (paper Table 1) ==")
    print(fmt_row("metric", "ours", "paper"))
    paper = {"accuracy": 0.9966, "precision": 0.9756, "specificity": 0.9960,
             "f1": 0.9877}
    for k in ("accuracy", "precision", "specificity", "f1"):
        print(fmt_row(k, f"{m[k]*100:.2f}%", f"{paper[k]*100:.2f}%"))

    imp = sorted(zip(SELECTED_KPMS, tree.importances), key=lambda kv: -kv[1])
    print("\nTop feature importances (paper: mac_throughput 94.27%):")
    for name, w in imp[:3]:
        print(fmt_row(name, f"{w*100:.2f}%"))

    return {"metrics": m, "n_test": int(len(y) - n_train),
            "top_feature": imp[0][0], "top_importance": float(imp[0][1])}


if __name__ == "__main__":
    run()
