"""Paper Table 1: decision-tree classification performance.

Trains the depth-2 Gini tree on interference-labelled slot telemetry
(profiled under both experts, 80/20 split) and reports accuracy / precision /
specificity / F1, plus the top feature importances (paper 5.3).

Also times the same tree through its *device* table export (the in-scan
closed-loop decision path, ``repro.core.closed_loop``): per-UE-batch
inference latency for the Pallas kernel and the literal-walk fallback,
printed alongside the host-object call the dApp uses — the host-loop vs
in-scan decision-latency comparison at the policy layer.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import campaign, fmt_row
from repro.core.policy import (
    DecisionTreePolicy,
    classification_metrics,
    fit_decision_tree,
)
from repro.core.telemetry import SELECTED_KPMS


def build_dataset(seed_pairs=((0, 1), (2, 3))) -> tuple[np.ndarray, np.ndarray]:
    X, y = [], []
    for s_good, s_poor in seed_pairs:
        for mode in (0, 1):
            for cond, label, seed in (("good", 1, s_good), ("poor", 0, s_poor)):
                data = campaign(mode, cond, seed=seed)
                rows = np.stack([data[n] for n in SELECTED_KPMS], axis=1)
                X.append(rows)
                y.append(np.full(rows.shape[0], label))
    return np.concatenate(X).astype(np.float32), np.concatenate(y).astype(np.int32)


def run() -> dict:
    X, y = build_dataset()
    rng = np.random.default_rng(0)
    perm = rng.permutation(len(y))
    X, y = X[perm], y[perm]
    n_train = int(0.8 * len(y))  # 80/20 split, as the paper
    tree = fit_decision_tree(X[:n_train], y[:n_train], depth=2)
    policy = DecisionTreePolicy(tree, SELECTED_KPMS)
    pred = np.asarray(policy.batch(X[n_train:]))
    m = classification_metrics(y[n_train:], pred)

    print("\n== Decision-tree performance (paper Table 1) ==")
    print(fmt_row("metric", "ours", "paper"))
    paper = {"accuracy": 0.9966, "precision": 0.9756, "specificity": 0.9960,
             "f1": 0.9877}
    for k in ("accuracy", "precision", "specificity", "f1"):
        print(fmt_row(k, f"{m[k]*100:.2f}%", f"{paper[k]*100:.2f}%"))

    imp = sorted(zip(SELECTED_KPMS, tree.importances), key=lambda kv: -kv[1])
    print("\nTop feature importances (paper: mac_throughput 94.27%):")
    for name, w in imp[:3]:
        print(fmt_row(name, f"{w*100:.2f}%"))

    device_stats = _device_inference_latency(policy, X[n_train:])
    return {"metrics": m, "n_test": int(len(y) - n_train),
            "top_feature": imp[0][0], "top_importance": float(imp[0][1]),
            **device_stats}


def _device_inference_latency(policy, X, n_ues: int = 16) -> dict:
    """Exported tree tables: per-decision latency, host call vs device batch."""
    from repro.core.closed_loop import policy_infer

    device = policy.to_device()
    xb = jnp.asarray(X[:n_ues], jnp.float32)
    prev = jnp.ones((xb.shape[0],), jnp.int32)

    def timed(fn, *args, reps=50):
        for _ in range(3):
            jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    backends = {}
    for backend in ("ref", "pallas"):
        fn = jax.jit(
            lambda x, p, b=backend: policy_infer(device, x, p, backend=b)
        )
        backends[backend] = timed(fn, xb, prev)
        # sanity: both backends agree with the host policy object
        got = np.asarray(fn(xb, prev))
        want = np.asarray(policy.batch(xb))
        np.testing.assert_array_equal(got, want)
    t_host = timed(lambda v: policy(v), jnp.asarray(X[0], jnp.float32))

    print(f"\nDevice tree-table inference ({n_ues}-UE batch, per decision):")
    print(fmt_row("host object (dApp path)", f"{t_host:.2f} us", "1 decision"))
    for backend, t in backends.items():
        print(fmt_row(f"device tables [{backend}]", f"{t / n_ues:.3f} us",
                      f"{t:.2f} us / {n_ues} UEs"))
    print(fmt_row("in-scan amortization", "see bench_control_loop",
                  "(decision folded into the slot scan)"))
    return {"t_host_decision_us": t_host,
            "t_device_ref_us_per_ue": backends["ref"] / n_ues,
            "t_device_pallas_us_per_ue": backends["pallas"] / n_ues}


if __name__ == "__main__":
    run()
