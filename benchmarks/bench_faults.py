"""Fault-injection campaigns + crash-resumable streaming (robustness PR).

Two legs, both doubling as CI smoke checks:

* **Fault-injected closed loop** — a campaign with every failure class
  armed (control-plane decision outages + per-slot drops, NaN expert
  corruption bursts feeding the health screen and circuit breaker,
  telemetry loss masking the rolling window) must replay **bitwise**
  through the host oracle (``ArchesSession.host_replay``): mode
  trajectories, raw decisions and quarantine spans; raises otherwise.
  Reports the warm fault-armed rate next to the clean closed loop's, and
  the degradation-ladder counters (health trips / quarantined slot-UEs)
  so the ladder is visibly non-vacuous.
* **Kill-and-resume streaming** — a churn campaign checkpointed at every
  segment boundary, killed after the first segment, resumed from the
  latest checkpoint: the stitched history must be bitwise-equal to the
  uninterrupted run on every leaf; raises otherwise.  Reports the
  checkpointed run's warm rate (the atomic fsync'd snapshot cost rides
  the segment loop) next to the checkpoint-free streaming rate.
"""

from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np


def _specs(n_slots: int, n_ues: int, segment_slots: int):
    from repro.core.faults import FaultSpec
    from repro.core.session import CampaignSpec, PolicySpec, SwitchSpec
    from repro.core.streaming import ChurnSchedule

    faults = FaultSpec(
        seed=3,
        decision_outages=((n_slots // 2, n_slots // 2 + 4),),
        decision_drop_prob=0.05,
        corruption_spans=((2, n_slots // 2 - 1),),
        corruption_kind="nan",
        telemetry_drop_prob=0.1,
        breaker_trips=2,
        breaker_window=4,
        breaker_cooldown=3,
    )
    base = dict(
        scenario="good_poor_good", n_ues=n_ues, n_slots=n_slots, seed=5,
        # always decide the AI expert: the mode trajectory is then a pure
        # function of the fault schedule (outage decay / quarantine)
        policies=(PolicySpec(kind="threshold", feature="snr",
                             threshold=1e9),),
        switch=SwitchSpec(window_slots=2, backend="ref", ttl_slots=3),
    )
    clean = CampaignSpec(path="closed_loop", **base)
    faulty = CampaignSpec(path="closed_loop", faults=faults, **base)
    streaming = CampaignSpec(
        path="closed_loop", faults=faults, **base,
        churn=ChurnSchedule(
            n_ue_ids=n_ues + 1, segment_slots=segment_slots,
            initial=tuple(range(n_ues - 1)),
            events=(
                (segment_slots, n_ues, "attach"),
                (segment_slots + 1, 0, "detach"),
                (segment_slots + 3, 0, "attach"),
            ),
        ),
    )
    return clean, faulty, streaming


def _time_warm(run, repeats: int = 3) -> float:
    run()  # compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        run()
    return (time.perf_counter() - t0) / repeats


def run(n_slots: int = 24, n_ues: int = 4, segment_slots: int = 8) -> dict:
    from repro.core.session import ArchesSession

    clean_spec, fault_spec, stream_spec = _specs(
        n_slots, n_ues, segment_slots
    )
    clean_sess = ArchesSession(clean_spec)
    fault_sess = ArchesSession(fault_spec, ai_params=clean_sess.ai_params)
    stream_sess = ArchesSession(stream_spec, ai_params=clean_sess.ai_params)

    # -- fault-injected closed loop: device == host oracle, bitwise ---------
    hist = fault_sess.run()
    replay = fault_sess.host_replay(hist)
    assert np.array_equal(
        np.asarray(hist.modes), replay["active_mode"]
    ), "fault-injected modes diverged from the host oracle"
    assert np.array_equal(
        np.asarray(hist.decisions), replay["raw_decision"]
    ), "fault-injected raw decisions diverged"
    assert np.array_equal(
        np.asarray(hist.outputs["quarantined"]) > 0,
        np.asarray(replay["quarantined"]) > 0,
    ), "quarantine spans diverged"
    trips = int((np.asarray(hist.outputs["health_tripped"]) > 0).sum())
    quar = int((np.asarray(hist.outputs["quarantined"]) > 0).sum())
    assert trips > 0, "vacuous: the corruption burst tripped nothing"
    assert quar > 0, "vacuous: the breaker never quarantined"

    clean_warm = _time_warm(clean_sess.run)
    fault_warm = _time_warm(fault_sess.run)
    clean_rate = n_slots * n_ues / clean_warm
    fault_rate = n_slots * n_ues / fault_warm
    print(f"fault replay: bitwise == host oracle on modes / raw decisions "
          f"/ quarantine ({n_slots}x{n_ues}, {trips} health trips, "
          f"{quar} quarantined slot-UEs)")
    print(f"clean loop:   {clean_rate:8.1f} slot-UEs/s warm")
    print(f"fault-armed:  {fault_rate:8.1f} slot-UEs/s warm "
          f"({clean_warm / fault_warm:.2f}x of clean; overhead is the "
          "corruption+screen pass and the TTL/breaker ladder)")

    # -- kill-and-resume streaming: stitched == uninterrupted, bitwise ------
    ref = stream_sess.run_streaming()
    with tempfile.TemporaryDirectory() as ckpt:
        stream_sess.run_streaming(checkpoint_dir=ckpt, max_segments=1)
        resumed = stream_sess.run_streaming(resume_from=ckpt)
        assert np.array_equal(
            np.asarray(ref.modes), np.asarray(resumed.modes)
        ), "resume: modes diverged from the uninterrupted run"
        for k in ref.kpms:
            assert np.array_equal(
                np.asarray(ref.kpms[k]), np.asarray(resumed.kpms[k])
            ), f"resume: kpm {k!r} diverged"
        for k in ref.outputs:
            assert np.array_equal(
                np.asarray(ref.outputs[k]), np.asarray(resumed.outputs[k])
            ), f"resume: output {k!r} diverged"
        np.testing.assert_array_equal(ref.attached, resumed.attached)

    stream_warm = _time_warm(stream_sess.run_streaming)
    with tempfile.TemporaryDirectory() as ckpt:
        ckpt_warm = _time_warm(
            lambda: stream_sess.run_streaming(checkpoint_dir=ckpt)
        )
    n_segments = (n_slots + segment_slots - 1) // segment_slots
    stream_rate = n_slots * n_ues / stream_warm
    ckpt_rate = n_slots * n_ues / ckpt_warm
    print(f"kill+resume:  bitwise == uninterrupted on every leaf "
          f"(killed after 1/{n_segments} segments)")
    print(f"streaming:    {stream_rate:8.1f} slot-UEs/s warm "
          "(fault-armed, no checkpoints)")
    print(f"checkpointed: {ckpt_rate:8.1f} slot-UEs/s warm "
          f"({stream_warm / ckpt_warm:.2f}x of checkpoint-free; overhead "
          "is the per-segment atomic fsync'd snapshot)")
    return {
        "fault_replay_equal": "bitwise",
        "resume_equal": "bitwise",
        "fault_closed_slot_ues_per_s": fault_rate,
        "clean_closed_slot_ues_per_s": clean_rate,
        "checkpointed_slot_ues_per_s": ckpt_rate,
        "streaming_fault_slot_ues_per_s": stream_rate,
        "health_tripped_slot_ues": trips,
        "quarantined_slot_ues": quar,
        "n_segments": n_segments,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-slots", type=int, default=24)
    ap.add_argument("--n-ues", type=int, default=4)
    ap.add_argument("--segment-slots", type=int, default=8)
    args = ap.parse_args()
    run(args.n_slots, args.n_ues, args.segment_slots)


if __name__ == "__main__":
    main()
