"""Schema/regression gate for the committed perf snapshot.

Two checks, both against the repo's committed ``BENCH_<tag>.json``:

1. **Schema compatibility** — the snapshot must parse, declare a
   compatible schema (``arches-bench-v1``; ``arches-bench-v2`` which adds
   the streaming/churn section; ``arches-bench-v3`` which additionally
   adds the fault-injection/crash-resume section; ``arches-bench-v4``
   which additionally adds the campaign-service section; or
   ``arches-bench-v5`` which extends the streaming section with the
   pipelined-executor rates and delta-checkpoint measurements), and carry
   every key current tooling reads (engine/gated/fused/bf16 rates, the
   campaign provenance hash, the host fingerprint).  A PR that renames a
   payload field without migrating the committed snapshot fails here, not
   six PRs later when someone plots the trajectory.

2. **Regression** — when a freshly measured candidate snapshot is supplied
   (``--candidate``, or automatically by ``benchmarks.run --smoke --json``),
   every ``*slot_ues_per_s`` rate is compared against the committed
   baseline.  A >20% drop on a *comparable* host (same platform, machine,
   CPU count, and JAX backend) exits non-zero; on a different host the
   deltas are printed as warnings only, since cross-host wall-clock is
   meaningless.

Usage:  PYTHONPATH=src python -m benchmarks.check_snapshot [BASELINE]
                                                           [--candidate NEW]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

#: the committed snapshot this repo's trajectory is anchored to
DEFAULT_BASELINE = Path(__file__).resolve().parent.parent / "BENCH_pr10.json"

#: wall-clock regression tolerance on comparable hosts
REGRESSION_FRAC = 0.20

#: the schema current tooling writes
SCHEMA = "arches-bench-v5"

#: schemas current tooling still reads: v1 snapshots predate the streaming
#: section (BENCH_pr6.json stays valid); v2 additionally requires it; v3
#: additionally requires the fault-injection/crash-resume section; v4
#: additionally requires the campaign-service section; v5 additionally
#: requires the pipelined-executor / delta-checkpoint streaming keys
SCHEMA_COMPAT = (
    "arches-bench-v1", "arches-bench-v2", "arches-bench-v3",
    "arches-bench-v4", "arches-bench-v5",
)

#: top-level keys every snapshot must carry
REQUIRED_KEYS = (
    "schema",
    "host",
    "slot_ues_per_s",
    "gated",
    "campaign_spec_hash",
)

#: keys the v2+ ``streaming`` section must carry
REQUIRED_STREAMING_KEYS = (
    "zero_churn_equal",
    "streaming_slot_ues_per_s",
    "monolithic_slot_ues_per_s",
    "churn_resident_slot_ues_per_s",
)

#: keys the v5 ``streaming`` section must additionally carry (pipelined
#: executor + O(segment) delta checkpoints)
REQUIRED_STREAMING_V5_KEYS = (
    "serial_checkpointed_slot_ues_per_s",
    "pipelined_checkpointed_slot_ues_per_s",
    "segment_breakdown_s",
    "delta_ckpt_bytes_per_segment",
    "delta_bytes_length_invariant",
)

#: keys the v3+ ``faults`` section must carry
REQUIRED_FAULTS_KEYS = (
    "fault_replay_equal",
    "resume_equal",
    "fault_closed_slot_ues_per_s",
    "checkpointed_slot_ues_per_s",
)

#: keys the v4 ``service`` section must carry
REQUIRED_SERVICE_KEYS = (
    "zero_churn_service_equal",
    "drain_resume_equal",
    "telemetry_exported",
    "telemetry_dropped",
    "service_campaign_wall_s",
)

#: per-share keys inside the ``gated`` section
REQUIRED_GATED_KEYS = (
    "executed_flops_per_slot",
    "gated_slot_ues_per_s",
    "concurrent_slot_ues_per_s",
    "fused_slot_ues_per_s",
    "bf16_slot_ues_per_s",
    "fused_speedup_vs_unfused",
    "bf16_audit_tripped",
)

#: the acceptance sweep: these AI shares must be present in every snapshot
REQUIRED_SHARES = ("0.0625", "0.25", "1")

#: host-fingerprint fields that must match for rate comparison
HOST_FIELDS = ("platform", "machine", "cpu_count", "jax_backend")


def _load(path: Path) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as exc:
        print(f"snapshot unreadable: {path}: {exc}")
        return None


def validate_schema(payload: dict, label: str) -> list[str]:
    """Return a list of schema violations (empty == compatible)."""
    errors: list[str] = []
    schema = payload.get("schema")
    if schema not in SCHEMA_COMPAT:
        errors.append(
            f"{label}: schema is {schema!r}, want one of {SCHEMA_COMPAT}"
        )
    for key in REQUIRED_KEYS:
        if key not in payload:
            errors.append(f"{label}: missing top-level key {key!r}")
    if schema in ("arches-bench-v2", "arches-bench-v3", "arches-bench-v4",
                  "arches-bench-v5"):
        streaming = payload.get("streaming")
        if streaming is None:
            errors.append(f"{label}: {schema[-2:]} snapshot missing "
                          "'streaming'")
        else:
            required = REQUIRED_STREAMING_KEYS + (
                REQUIRED_STREAMING_V5_KEYS
                if schema == "arches-bench-v5" else ()
            )
            for key in required:
                if key not in streaming:
                    errors.append(f"{label}: streaming missing {key!r}")
    if schema in ("arches-bench-v3", "arches-bench-v4", "arches-bench-v5"):
        faults = payload.get("faults")
        if faults is None:
            errors.append(f"{label}: {schema[-2:]} snapshot missing "
                          "'faults'")
        else:
            for key in REQUIRED_FAULTS_KEYS:
                if key not in faults:
                    errors.append(f"{label}: faults missing {key!r}")
    if schema in ("arches-bench-v4", "arches-bench-v5"):
        service = payload.get("service")
        if service is None:
            errors.append(f"{label}: {schema[-2:]} snapshot missing "
                          "'service'")
        else:
            for key in REQUIRED_SERVICE_KEYS:
                if key not in service:
                    errors.append(f"{label}: service missing {key!r}")
    host = payload.get("host", {})
    for field in HOST_FIELDS:
        if field not in host:
            errors.append(f"{label}: host fingerprint missing {field!r}")
    gated = payload.get("gated", {})
    for share in REQUIRED_SHARES:
        if share not in gated:
            errors.append(f"{label}: gated sweep missing AI share {share!r}")
    for share, row in gated.items():
        for key in REQUIRED_GATED_KEYS:
            if key not in row:
                errors.append(f"{label}: gated[{share!r}] missing {key!r}")
    return errors


def _rates(payload: dict, prefix: str = "") -> dict[str, float]:
    """Flatten every ``*slot_ues_per_s`` scalar out of the payload."""
    found: dict[str, float] = {}
    for key, val in payload.items():
        path = f"{prefix}{key}"
        if isinstance(val, dict):
            found.update(_rates(val, prefix=f"{path}."))
        elif key.endswith("slot_ues_per_s") and isinstance(val, (int, float)):
            found[path] = float(val)
    return found


def check(baseline: Path | str, candidate: Path | str | None = None) -> int:
    """Run both gates; return a process exit code (0 == pass)."""
    baseline = Path(baseline)
    base = _load(baseline)
    if base is None:
        return 1
    errors = validate_schema(base, baseline.name)
    for err in errors:
        print(f"SCHEMA  {err}")
    if errors:
        return 1
    print(f"schema ok: {baseline.name} ({base.get('schema')})")

    if candidate is None:
        return 0
    candidate = Path(candidate)
    if candidate.resolve() == baseline.resolve():
        print("candidate is the baseline itself; nothing to compare")
        return 0
    cand = _load(candidate)
    if cand is None:
        return 1
    errors = validate_schema(cand, candidate.name)
    for err in errors:
        print(f"SCHEMA  {err}")
    if errors:
        return 1

    comparable = all(
        base.get("host", {}).get(f) == cand.get("host", {}).get(f)
        for f in HOST_FIELDS
    )
    base_rates, cand_rates = _rates(base), _rates(cand)
    regressions = []
    for key, ref in sorted(base_rates.items()):
        new = cand_rates.get(key)
        if new is None or ref <= 0:
            continue
        delta = (new - ref) / ref
        marker = ""
        if delta < -REGRESSION_FRAC:
            marker = " <-- REGRESSION" if comparable else " (different host)"
            regressions.append((key, ref, new, delta))
        print(f"  {key}: {ref:.1f} -> {new:.1f} ({delta:+.1%}){marker}")
    if regressions and comparable:
        print(
            f"{len(regressions)} rate(s) regressed >{REGRESSION_FRAC:.0%} "
            f"on a comparable host"
        )
        return 1
    if regressions:
        print(
            f"warning: {len(regressions)} rate(s) dropped >"
            f"{REGRESSION_FRAC:.0%}, but hosts differ — not failing"
        )
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", nargs="?", default=DEFAULT_BASELINE,
                    help="committed snapshot (default: BENCH_pr10.json)")
    ap.add_argument("--candidate", default=None,
                    help="freshly measured snapshot to diff against baseline")
    args = ap.parse_args()
    sys.exit(check(args.baseline, candidate=args.candidate))


if __name__ == "__main__":
    main()
